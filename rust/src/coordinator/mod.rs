//! Trial coordinator: schedules grids of training runs across a worker
//! pool and aggregates results (Table 1 / Fig. 3 machinery).
//!
//! PJRT clients are not `Send`, so each worker *creates its own
//! [`Runtime`]* inside the thread; trials are chunked so one worker
//! amortizes its artifact compilation over its whole chunk.
//!
//! Parallelism is budgeted through one shared [`ExecContext`]: trial-level
//! workers come from the context's pool (created once, reused across
//! grids — no per-grid pool churn), and each trial receives a
//! [`ExecContext::partition`]ed shard-level context so total concurrency
//! stays at the caller's budget instead of multiplying against it.
//!
//! Grids are elastic (DESIGN.md §11): with a checkpoint directory
//! configured, every trial snapshots into its own subdirectory, a killed
//! grid resumed with [`crate::snapshot::CheckpointConfig::resume`] skips
//! trials whose `completed/` outcome record is on disk, and in-flight
//! trials continue bitwise-identically from their newest valid snapshot.

use anyhow::{anyhow, Result};

use crate::config::{Manifest, TrainMode};
use crate::data::Corpus;
use crate::eval::Evaluator;
use crate::exec::ExecContext;
use crate::metrics::probe_tracker;
use crate::oracle::PjrtOracle;
use crate::runtime::Runtime;
use crate::snapshot::{self, CheckpointConfig};
use crate::train::{ProbeDispatch, ProbeStorage, TrainConfig, TrainOutcome, Trainer};

/// One training run to schedule.
#[derive(Clone, Debug)]
pub struct TrialSpec {
    /// Stable identifier used to match results back to specs.
    pub id: String,
    /// Manifest model name.
    pub model: String,
    /// Full fine-tuning or LoRA.
    pub mode: TrainMode,
    /// The training-run configuration.
    pub config: TrainConfig,
    /// Test batches per evaluation point (overrides the config's value).
    pub eval_batches: usize,
    /// Per-trial override of the probe-dispatch mode (None keeps the
    /// config's).  The CLI `train --probe-dispatch` flag flows through
    /// here; grids can use it to A/B fused vs per-probe dispatch without
    /// cloning configs by hand.
    pub probe_dispatch: Option<ProbeDispatch>,
    /// Per-trial override of the probe storage (None keeps the config's).
    /// The CLI `train --probe-storage` flag flows through here; grids can
    /// use it to A/B materialized vs streamed without cloning configs.
    pub probe_storage: Option<ProbeStorage>,
    /// Per-trial override of the checkpoint/resume policy (None keeps the
    /// config's).  Either way, a grid-level checkpoint directory is
    /// rewritten to a per-trial subdirectory (`<dir>/<sanitized id>`)
    /// before the trainer sees it, so trials never clobber each other's
    /// snapshots.
    pub checkpoint: Option<CheckpointConfig>,
}

/// Outcome of one scheduled trial.
#[derive(Clone, Debug)]
pub struct TrialResult {
    /// The [`TrialSpec::id`] this result belongs to.
    pub spec_id: String,
    /// The training-run outcome.
    pub outcome: TrainOutcome,
    /// The probe storage the run *resolved to* ("materialized" |
    /// "streamed") after the env override, memory budget, and capability
    /// fallbacks — which may differ from what the spec requested.
    pub probe_storage: &'static str,
    /// Measured peak probe-state bytes (probe matrices + streaming
    /// scratch, from [`crate::metrics::probe_tracker`]).  For serial
    /// schedules — [`run_trial`] and one-worker grids — the tracker is
    /// reset at the start of the trial and this is the trial's exact
    /// peak, never inheriting an earlier trial's high-water mark.  The
    /// tracker is process-wide, so concurrent grids cannot attribute
    /// peaks to individual trials; [`run_grid`] then reports the
    /// *grid-wide* peak (one measurement window around the whole grid)
    /// on every result — a shared upper bound rather than a per-trial
    /// number.
    pub probe_peak_bytes: usize,
}

/// Run one trial on the current thread (used by workers and by the
/// single-threaded CLI path).  `exec` is the shard-level execution context
/// the trial's train loop runs on.  The probe-memory tracker is reset at
/// trial start, so [`TrialResult::probe_peak_bytes`] is this trial's
/// exact peak (serial-schedule measurement; concurrent grids go through
/// [`run_grid`], which measures grid-wide instead).
pub fn run_trial(
    artifact_dir: &str,
    manifest: &Manifest,
    spec: &TrialSpec,
    rt: &Runtime,
    exec: &ExecContext,
) -> Result<TrialResult> {
    run_trial_measured(artifact_dir, manifest, spec, rt, exec, true)
}

/// [`run_trial`] with the per-trial probe-memory window made optional:
/// concurrent grid workers pass `measure = false` (a process-wide
/// tracker cannot attribute peaks to one of several live trials — and a
/// mid-grid reset would clamp a neighbour's transient peak away) and let
/// [`run_grid`] bracket the whole grid with one measurement window.
fn run_trial_measured(
    artifact_dir: &str,
    manifest: &Manifest,
    spec: &TrialSpec,
    rt: &Runtime,
    exec: &ExecContext,
    measure: bool,
) -> Result<TrialResult> {
    let entry = manifest.model(&spec.model)?;
    let corpus_spec = manifest.corpus(&spec.model)?.clone();
    let mut cfg = spec.config.clone();
    cfg.eval_batches = spec.eval_batches;
    if let Some(dispatch) = spec.probe_dispatch {
        cfg.probe_dispatch = dispatch;
    }
    if let Some(storage) = spec.probe_storage {
        cfg.probe_storage = storage;
    }
    if let Some(ck) = &spec.checkpoint {
        cfg.checkpoint = ck.clone();
    }
    // Rewrite a grid-level checkpoint base to this trial's private
    // subdirectory; a resumed grid short-circuits trials whose completed
    // outcome record is already on disk.
    let trial_ck_dir = cfg
        .checkpoint
        .dir
        .as_ref()
        .map(|base| std::path::Path::new(base).join(snapshot::sanitize_id(&spec.id)));
    if let Some(tdir) = &trial_ck_dir {
        cfg.checkpoint.dir = Some(tdir.to_string_lossy().into_owned());
        if cfg.checkpoint.resume {
            if let Some(rec) = snapshot::load_outcome(tdir) {
                // Validate the record against the spec's configuration
                // before reusing it — trial ids don't encode seed/budget/
                // method, so a config edit between grid runs must re-run
                // the trial, not silently serve stale numbers.  (The
                // re-run then hits the same mismatch on any leftover
                // snapshot via the trainer's fingerprint check, which
                // errors loudly.)
                let expected_label =
                    format!("{}+{}", cfg.estimator.label(), cfg.optimizer);
                if rec.outcome.label == expected_label
                    && rec.seed == cfg.seed
                    && rec.budget == cfg.budget
                {
                    return Ok(TrialResult {
                        spec_id: spec.id.clone(),
                        outcome: rec.outcome,
                        probe_storage: storage_label_static(&rec.probe_storage),
                        probe_peak_bytes: 0,
                    });
                }
                eprintln!(
                    "coordinator: completed record in {} is for {} (seed {}, \
                     budget {}), run wants {expected_label} (seed {}, budget \
                     {}) — re-running trial",
                    tdir.display(),
                    rec.outcome.label,
                    rec.seed,
                    rec.budget,
                    cfg.seed,
                    cfg.budget,
                );
            }
        }
    }
    let oracle = PjrtOracle::new(rt, entry, spec.mode)?;
    let evaluator = Evaluator::new(rt, entry, spec.mode)?;
    let corpus = Corpus::new(corpus_spec)?;
    // per-trial probe-memory window: without this reset, every trial
    // after the first reported the run's cumulative high-water mark
    // instead of its own peak
    if measure {
        probe_tracker().reset();
    }
    // (cfg moves into the trainer; keep the identity fields the completed
    // record is stamped with)
    let (cfg_seed, cfg_budget) = (cfg.seed, cfg.budget);
    let mut trainer = Trainer::with_exec(cfg, oracle, corpus, exec.clone())?;
    let probe_storage = trainer.estimator().probes().label();
    let outcome = trainer.run(Some(&evaluator))?;
    let probe_peak_bytes = if measure { probe_tracker().peak() } else { 0 };
    if outcome.completed {
        if let Some(tdir) = &trial_ck_dir {
            // persist the finished trial so a resumed grid skips it
            snapshot::write_outcome(tdir, &outcome, probe_storage, cfg_seed, cfg_budget)?;
        }
    }
    let _ = artifact_dir;
    Ok(TrialResult { spec_id: spec.id.clone(), outcome, probe_storage, probe_peak_bytes })
}

/// Map a stored probe-storage label back onto the static strings
/// [`TrialResult::probe_storage`] carries.
fn storage_label_static(label: &str) -> &'static str {
    match label {
        "streamed" => "streamed",
        "auto" => "auto",
        _ => "materialized",
    }
}

/// Run a batch of trials on the shared execution context.  Trial-level
/// workers come from `exec`'s pool (reused across grids); each trial gets
/// a partitioned shard-level context so the two levels share one worker
/// budget.  Results come back in spec order; per-trial failures are
/// isolated into `Err` strings.  Probe-memory peaks are exact per trial
/// on one-worker grids and grid-wide (stamped on every result) otherwise
/// — see [`TrialResult::probe_peak_bytes`].
pub fn run_grid(
    artifact_dir: &str,
    specs: Vec<TrialSpec>,
    exec: &ExecContext,
) -> Vec<Result<TrialResult>> {
    let workers = exec.threads().max(1).min(specs.len().max(1));
    let pool = exec.pool();
    let shard_exec = exec.partition(workers);
    // Probe-memory measurement: with one worker, trials are serial and
    // each gets its own exact per-trial window; with several, the
    // process-wide tracker cannot attribute peaks per trial, so one
    // grid-wide window brackets the whole grid and its peak is stamped
    // on every result below (a shared upper bound).
    let per_trial_peaks = workers <= 1;
    if !per_trial_peaks {
        probe_tracker().reset();
    }
    // chunk specs round-robin so each worker compiles its artifacts once
    let mut chunks: Vec<Vec<(usize, TrialSpec)>> = vec![Vec::new(); workers];
    for (i, spec) in specs.into_iter().enumerate() {
        chunks[i % workers].push((i, spec));
    }
    let dir = artifact_dir.to_string();
    let chunk_results = pool.scope_map(chunks, move |chunk| {
        let mut out: Vec<(usize, Result<TrialResult, String>)> = Vec::new();
        // one runtime + manifest per worker thread
        let rt = Runtime::new(&dir);
        let manifest = Manifest::load(&dir);
        match (&rt, &manifest) {
            (Ok(rt), Ok(manifest)) => {
                for (i, spec) in chunk {
                    let r = run_trial_measured(
                        &dir,
                        manifest,
                        &spec,
                        rt,
                        &shard_exec,
                        per_trial_peaks,
                    )
                    .map_err(|e| format!("{e:#}"));
                    out.push((i, r));
                }
            }
            (Err(e), _) => {
                for (i, _) in chunk {
                    out.push((i, Err(format!("runtime init: {e:#}"))));
                }
            }
            (_, Err(e)) => {
                for (i, _) in chunk {
                    out.push((i, Err(format!("manifest load: {e:#}"))));
                }
            }
        }
        out
    });
    // flatten, restore order
    let mut indexed: Vec<(usize, Result<TrialResult, String>)> = Vec::new();
    for c in chunk_results {
        match c {
            Ok(items) => indexed.extend(items),
            Err(panic_msg) => {
                // a whole worker chunk panicked; surface it once
                indexed.push((usize::MAX, Err(panic_msg)));
            }
        }
    }
    indexed.sort_by_key(|(i, _)| *i);
    let grid_peak = if per_trial_peaks { 0 } else { probe_tracker().peak() };
    indexed
        .into_iter()
        .map(|(_, r)| {
            r.map(|mut tr| {
                if !per_trial_peaks {
                    tr.probe_peak_bytes = grid_peak;
                }
                tr
            })
            .map_err(|e| anyhow!(e))
        })
        .collect()
}

/// Accuracy aggregation across seed-replicated specs with an explicit
/// sample count: an empty result slice yields `n = 0` and `None` stats
/// instead of NaNs that would propagate into grid summaries (and turn
/// into `null` in report JSON).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct AccuracyAggregate {
    /// Number of results aggregated.
    pub n: usize,
    /// Mean final accuracy (None when `n == 0`).
    pub mean: Option<f64>,
    /// Sample standard deviation (None when `n == 0`; 0 for `n == 1`).
    pub std: Option<f64>,
}

impl AccuracyAggregate {
    /// Render as `mean ± std (n)` or `n=0` for tables.
    pub fn display(&self) -> String {
        match (self.mean, self.std) {
            (Some(m), Some(s)) => format!("{m:.4} ± {s:.4} (n={})", self.n),
            _ => "n=0".to_string(),
        }
    }
}

/// Mean/std aggregation of final accuracy across seed-replicated specs.
/// Empty input reports `n = 0` explicitly rather than NaN stats.
pub fn aggregate_accuracy(results: &[&TrialResult]) -> AccuracyAggregate {
    if results.is_empty() {
        return AccuracyAggregate::default();
    }
    let accs: Vec<f64> = results.iter().map(|r| r.outcome.final_accuracy).collect();
    AccuracyAggregate {
        n: accs.len(),
        mean: Some(crate::metrics::mean(&accs)),
        std: Some(crate::metrics::stddev(&accs)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregate_mean_std() {
        let mk = |acc: f64| TrialResult {
            spec_id: "s".into(),
            outcome: TrainOutcome { final_accuracy: acc, ..Default::default() },
            probe_storage: "materialized",
            probe_peak_bytes: 0,
        };
        let a = mk(0.8);
        let b = mk(0.9);
        let agg = aggregate_accuracy(&[&a, &b]);
        assert_eq!(agg.n, 2);
        assert!((agg.mean.unwrap() - 0.85).abs() < 1e-12);
        assert!(agg.std.unwrap() > 0.0);
        assert!(agg.display().contains("n=2"));
    }

    #[test]
    fn aggregate_empty_reports_n_zero_not_nan() {
        let agg = aggregate_accuracy(&[]);
        assert_eq!(agg.n, 0);
        assert_eq!(agg.mean, None);
        assert_eq!(agg.std, None);
        assert_eq!(agg.display(), "n=0");
    }
}
