//! Configuration substrate: artifact manifest + experiment configs.
//!
//! * [`Manifest`] — typed view of `artifacts/manifest.json` (the L2->L3
//!   ABI: shapes, parameter layouts, pretrain stats, artifact inventory).
//! * [`kvconf`] — a tiny `key = value` config-file format with sections,
//!   includes and CLI overrides, used by the experiment launcher.

pub mod kvconf;
mod manifest;

pub use kvconf::KvConfig;
pub use manifest::{
    ArtifactInfo, LayoutEntry, Manifest, ModelEntry, ModelShapes, TrainMode,
};
