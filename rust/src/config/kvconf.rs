//! `key = value` experiment-config files with sections and overrides.
//!
//! Format (a pragmatic TOML subset — the vendor set has no serde/toml):
//!
//! ```text
//! # comment
//! model = roberta_mini
//! [optimizer]
//! name = zo_sgd
//! lr = 1e-6
//! momentum = 0.9
//! ```
//!
//! Section keys flatten to `section.key`.  CLI overrides (`--set a.b=c`)
//! are applied on top with `apply_override`.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// Flattened `section.key -> value` config map.
#[derive(Clone, Debug, Default)]
pub struct KvConfig {
    entries: BTreeMap<String, String>,
}

impl KvConfig {
    /// Parse config text (see the module docs for the format).
    pub fn parse(text: &str) -> Result<Self> {
        let mut entries = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| anyhow!("line {}: unclosed section", lineno + 1))?
                    .trim();
                if name.is_empty() {
                    bail!("line {}: empty section name", lineno + 1);
                }
                section = name.to_string();
                continue;
            }
            let (key, value) = line
                .split_once('=')
                .ok_or_else(|| anyhow!("line {}: expected key = value", lineno + 1))?;
            let key = key.trim();
            if key.is_empty() {
                bail!("line {}: empty key", lineno + 1);
            }
            let full = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            entries.insert(full, unquote(value.trim()));
        }
        Ok(Self { entries })
    }

    /// Parse a config file from disk.
    pub fn load(path: &std::path::Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading {}: {e}", path.display()))?;
        Self::parse(&text)
    }

    /// Apply a `key=value` override (CLI `--set`).
    pub fn apply_override(&mut self, spec: &str) -> Result<()> {
        let (k, v) = spec
            .split_once('=')
            .ok_or_else(|| anyhow!("override '{spec}' must be key=value"))?;
        self.entries.insert(k.trim().to_string(), unquote(v.trim()));
        Ok(())
    }

    /// Set a key programmatically (CLI options layered over files).
    pub fn set(&mut self, key: &str, value: &str) {
        self.entries.insert(key.to_string(), value.to_string());
    }

    /// Raw string value of a flattened key.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.entries.get(key).map(String::as_str)
    }

    /// Like [`KvConfig::get`] with a default.
    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    /// Error if the key is absent.
    pub fn require(&self, key: &str) -> Result<&str> {
        self.get(key).ok_or_else(|| anyhow!("missing config key '{key}'"))
    }

    /// Typed accessor: f64 (None if absent, error if unparsable).
    pub fn get_f64(&self, key: &str) -> Result<Option<f64>> {
        match self.get(key) {
            None => Ok(None),
            Some(s) => s
                .parse()
                .map(Some)
                .map_err(|e| anyhow!("config '{key}' = '{s}': {e}")),
        }
    }

    /// Typed accessor: f64 with default.
    pub fn get_f64_or(&self, key: &str, default: f64) -> Result<f64> {
        Ok(self.get_f64(key)?.unwrap_or(default))
    }

    /// Typed accessor: usize (None if absent, error if unparsable).
    pub fn get_usize(&self, key: &str) -> Result<Option<usize>> {
        match self.get(key) {
            None => Ok(None),
            Some(s) => s
                .parse()
                .map(Some)
                .map_err(|e| anyhow!("config '{key}' = '{s}': {e}")),
        }
    }

    /// Typed accessor: usize with default.
    pub fn get_usize_or(&self, key: &str, default: usize) -> Result<usize> {
        Ok(self.get_usize(key)?.unwrap_or(default))
    }

    /// Typed accessor: u64 with default.
    pub fn get_u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(s) => s.parse().map_err(|e| anyhow!("config '{key}' = '{s}': {e}")),
        }
    }

    /// Typed accessor: bool with default ("true"/"1"/"yes" etc.).
    pub fn get_bool_or(&self, key: &str, default: bool) -> Result<bool> {
        match self.get(key) {
            None => Ok(default),
            Some("true" | "1" | "yes") => Ok(true),
            Some("false" | "0" | "no") => Ok(false),
            Some(s) => bail!("config '{key}' = '{s}' is not a boolean"),
        }
    }

    /// All flattened keys in sorted order.
    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.entries.keys().map(String::as_str)
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside quotes
    let mut in_quotes = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_quotes = !in_quotes,
            '#' if !in_quotes => return &line[..i],
            _ => {}
        }
    }
    line
}

fn unquote(s: &str) -> String {
    if s.len() >= 2 && s.starts_with('"') && s.ends_with('"') {
        s[1..s.len() - 1].to_string()
    } else {
        s.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let c = KvConfig::parse(
            "model = roberta_mini # inline comment\n\
             [optimizer]\n\
             name = \"zo_sgd\"\n\
             lr = 1e-6\n\
             steps = 400\n\
             nesterov = true\n",
        )
        .unwrap();
        assert_eq!(c.get("model"), Some("roberta_mini"));
        assert_eq!(c.get("optimizer.name"), Some("zo_sgd"));
        assert_eq!(c.get_f64("optimizer.lr").unwrap(), Some(1e-6));
        assert_eq!(c.get_usize("optimizer.steps").unwrap(), Some(400));
        assert!(c.get_bool_or("optimizer.nesterov", false).unwrap());
    }

    #[test]
    fn overrides_win() {
        let mut c = KvConfig::parse("a = 1\n").unwrap();
        c.apply_override("a=2").unwrap();
        c.apply_override("b.c=3").unwrap();
        assert_eq!(c.get("a"), Some("2"));
        assert_eq!(c.get("b.c"), Some("3"));
    }

    #[test]
    fn errors_are_descriptive() {
        assert!(KvConfig::parse("[open\n").is_err());
        assert!(KvConfig::parse("novalue\n").is_err());
        let c = KvConfig::parse("x = notanumber\n").unwrap();
        assert!(c.get_f64("x").is_err());
        assert!(c.require("nope").is_err());
    }

    #[test]
    fn hash_inside_quotes_kept() {
        let c = KvConfig::parse("s = \"a#b\"\n").unwrap();
        assert_eq!(c.get("s"), Some("a#b"));
    }
}
