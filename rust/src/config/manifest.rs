//! Typed loader for artifacts/manifest.json (MANIFEST_VERSION guarded).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::data::corpus::CorpusSpec;
use crate::jsonio::{parse, Json};

/// Manifest schema version this loader understands (mirrors
/// `python/compile/aot.py::MANIFEST_VERSION`).
pub const SUPPORTED_VERSION: u64 = 3;

/// Which parameter set is trainable (and therefore perturbed).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TrainMode {
    /// Full fine-tuning: all d_ft parameters are trainable.
    Ft,
    /// LoRA: only the d_lora adapter vector is trainable.
    Lora,
}

impl TrainMode {
    /// Canonical lowercase name ("ft" | "lora").
    pub fn as_str(&self) -> &'static str {
        match self {
            TrainMode::Ft => "ft",
            TrainMode::Lora => "lora",
        }
    }

    /// Parse from a CLI/config string.
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "ft" => Ok(TrainMode::Ft),
            "lora" => Ok(TrainMode::Lora),
            _ => bail!("unknown train mode '{s}' (expected ft|lora)"),
        }
    }
}

/// One named tensor's slice of the flat parameter vector.
#[derive(Clone, Debug)]
pub struct LayoutEntry {
    /// Tensor name (python-side pytree path).
    pub name: String,
    /// Tensor shape.
    pub shape: Vec<usize>,
    /// Start offset into the flat vector.
    pub offset: usize,
    /// Element count (product of shape).
    pub len: usize,
}

/// Inventory record for one lowered HLO artifact.
#[derive(Clone, Debug)]
pub struct ArtifactInfo {
    /// File name inside the artifact directory.
    pub file: String,
    /// File size in bytes (0 if unrecorded).
    pub bytes: usize,
}

/// Static shapes of a model's artifacts.
#[derive(Clone, Copy, Debug)]
pub struct ModelShapes {
    /// Training batch size the loss graphs were lowered for.
    pub batch: usize,
    /// Eval batch size the logits graph was lowered for.
    pub eval_batch: usize,
    /// Sequence length.
    pub seq: usize,
    /// Probe count K baked into the fused `loss_k` artifact.
    pub k: usize,
    /// Classifier output classes.
    pub n_classes: usize,
}

/// One model's manifest entry: dimensions, layouts, artifact inventory.
#[derive(Clone, Debug)]
pub struct ModelEntry {
    /// Model name (manifest key).
    pub name: String,
    /// Full fine-tuning dimensionality.
    pub d_ft: usize,
    /// LoRA adapter dimensionality.
    pub d_lora: usize,
    /// Static artifact shapes.
    pub shapes: ModelShapes,
    /// Causal (decoder) vs bidirectional attention.
    pub causal: bool,
    /// Pooling strategy for the classifier head ("cls" | "mean").
    pub pool: String,
    /// Vocabulary size.
    pub vocab: usize,
    /// Hidden width.
    pub d_model: usize,
    /// Transformer depth.
    pub n_layers: usize,
    /// Flat-vector layout of the full parameter set.
    pub layout_ft: Vec<LayoutEntry>,
    /// Flat-vector layout of the LoRA adapter set.
    pub layout_lora: Vec<LayoutEntry>,
    /// File holding the pretrained flat f32 parameters.
    pub params_file: String,
    /// File holding the LoRA adapter initialization.
    pub lora_init_file: String,
    /// held-out accuracy of the pretrained checkpoint (trained head)
    pub pretrain_accuracy: Option<f64>,
    /// accuracy after head re-initialization (what rust fine-tuning starts
    /// from; ~chance level)
    pub init_accuracy: Option<f64>,
    /// Artifact inventory by graph name.
    pub artifacts: BTreeMap<String, ArtifactInfo>,
}

impl ModelEntry {
    /// Trainable dimensionality under a mode.
    pub fn d_trainable(&self, mode: TrainMode) -> usize {
        match mode {
            TrainMode::Ft => self.d_ft,
            TrainMode::Lora => self.d_lora,
        }
    }

    /// Artifact name (runtime cache key) for a graph of this model.
    pub fn artifact(&self, mode: TrainMode, fn_name: &str) -> String {
        format!("{}_{}_{}", self.name, mode.as_str(), fn_name)
    }
}

/// Typed view of `artifacts/manifest.json` — the L2->L3 ABI.
#[derive(Clone, Debug)]
pub struct Manifest {
    /// Schema version (must equal [`SUPPORTED_VERSION`]).
    pub version: u64,
    /// Model entries by name.
    pub models: BTreeMap<String, ModelEntry>,
    /// Corpus specs keyed by model name.
    pub corpora: BTreeMap<String, CorpusSpec>,
    /// Toy (Fig. 2) problem dimensionality.
    pub toy_d: usize,
    /// Toy problem sample count.
    pub toy_n: usize,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load<P: AsRef<Path>>(dir: P) -> Result<Self> {
        let path = dir.as_ref().join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::from_json_text(&text)
    }

    /// Parse from JSON text (version-checked).
    pub fn from_json_text(text: &str) -> Result<Self> {
        let root = parse(text).map_err(|e| anyhow!("manifest: {e}"))?;
        let version = root
            .field("version")
            .map_err(|e| anyhow!("{e}"))?
            .as_u64()
            .ok_or_else(|| anyhow!("manifest version not an integer"))?;
        if version != SUPPORTED_VERSION {
            bail!(
                "manifest version {version} unsupported (want {SUPPORTED_VERSION}); \
                 re-run `make artifacts`"
            );
        }
        let mut corpora = BTreeMap::new();
        if let Some(cs) = root.get("corpus").and_then(Json::as_obj) {
            for (name, c) in cs {
                corpora.insert(name.clone(), parse_corpus(c)?);
            }
        }
        let mut models = BTreeMap::new();
        let model_obj = root
            .field("models")
            .map_err(|e| anyhow!("{e}"))?
            .as_obj()
            .ok_or_else(|| anyhow!("models is not an object"))?;
        for (name, m) in model_obj {
            models.insert(name.clone(), parse_model(name, m)?);
        }
        let toy = root.get("toy");
        let toy_d = toy
            .and_then(|t| t.get("d"))
            .and_then(Json::as_usize)
            .unwrap_or(0);
        let toy_n = toy
            .and_then(|t| t.get("n"))
            .and_then(Json::as_usize)
            .unwrap_or(0);
        Ok(Self { version, models, corpora, toy_d, toy_n })
    }

    /// Look up a model entry (error lists known names).
    pub fn model(&self, name: &str) -> Result<&ModelEntry> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow!("model '{name}' not in manifest (have: {:?})",
                self.models.keys().collect::<Vec<_>>()))
    }

    /// Look up the corpus spec for a model.
    pub fn corpus(&self, model: &str) -> Result<&CorpusSpec> {
        self.corpora
            .get(model)
            .ok_or_else(|| anyhow!("no corpus spec for model '{model}'"))
    }
}

fn usize_field(j: &Json, key: &str) -> Result<usize> {
    j.field(key)
        .map_err(|e| anyhow!("{e}"))?
        .as_usize()
        .ok_or_else(|| anyhow!("field '{key}' is not a non-negative integer"))
}

fn f64_field(j: &Json, key: &str) -> Result<f64> {
    j.field(key)
        .map_err(|e| anyhow!("{e}"))?
        .as_f64()
        .ok_or_else(|| anyhow!("field '{key}' is not a number"))
}

fn parse_corpus(c: &Json) -> Result<CorpusSpec> {
    Ok(CorpusSpec {
        vocab: usize_field(c, "vocab")? as u64,
        seq: usize_field(c, "seq")?,
        n_classes: usize_field(c, "n_classes")? as u64,
        lexicon: usize_field(c, "lexicon")? as u64,
        min_len: usize_field(c, "min_len")? as u64,
        signal_min: usize_field(c, "signal_min")? as u64,
        signal_max: usize_field(c, "signal_max")? as u64,
        contra: f64_field(c, "contra")?,
        noise: f64_field(c, "noise")?,
        seed: c
            .field("seed")
            .map_err(|e| anyhow!("{e}"))?
            .as_u64()
            .ok_or_else(|| anyhow!("corpus seed not an integer"))?,
    })
}

fn parse_layout(j: &Json) -> Result<Vec<LayoutEntry>> {
    let arr = j.as_arr().ok_or_else(|| anyhow!("layout is not an array"))?;
    let mut out = Vec::with_capacity(arr.len());
    let mut offset = 0usize;
    for e in arr {
        let name = e
            .field("name")
            .map_err(|er| anyhow!("{er}"))?
            .as_str()
            .ok_or_else(|| anyhow!("layout name not a string"))?
            .to_string();
        let shape: Vec<usize> = e
            .field("shape")
            .map_err(|er| anyhow!("{er}"))?
            .as_arr()
            .ok_or_else(|| anyhow!("layout shape not an array"))?
            .iter()
            .map(|s| s.as_usize().ok_or_else(|| anyhow!("bad shape dim")))
            .collect::<Result<_>>()?;
        let len: usize = shape.iter().product();
        out.push(LayoutEntry { name, shape, offset, len });
        offset += len;
    }
    Ok(out)
}

fn parse_model(name: &str, m: &Json) -> Result<ModelEntry> {
    let cfg = m.field("config").map_err(|e| anyhow!("{e}"))?;
    let layout_ft = parse_layout(m.field("layout_ft").map_err(|e| anyhow!("{e}"))?)?;
    let layout_lora =
        parse_layout(m.field("layout_lora").map_err(|e| anyhow!("{e}"))?)?;
    let d_ft = usize_field(m, "d_ft")?;
    let d_lora = usize_field(m, "d_lora")?;
    // layout/offset consistency is an ABI invariant; check it eagerly
    let sum_ft: usize = layout_ft.iter().map(|l| l.len).sum();
    if sum_ft != d_ft {
        bail!("model {name}: layout_ft sums to {sum_ft}, manifest d_ft={d_ft}");
    }
    let sum_lora: usize = layout_lora.iter().map(|l| l.len).sum();
    if sum_lora != d_lora {
        bail!("model {name}: layout_lora sums to {sum_lora}, d_lora={d_lora}");
    }
    let mut artifacts = BTreeMap::new();
    if let Some(arts) = m.get("artifacts").and_then(Json::as_obj) {
        for (aname, a) in arts {
            artifacts.insert(
                aname.clone(),
                ArtifactInfo {
                    file: a
                        .field("file")
                        .map_err(|e| anyhow!("{e}"))?
                        .as_str()
                        .unwrap_or_default()
                        .to_string(),
                    bytes: a.get("bytes").and_then(Json::as_usize).unwrap_or(0),
                },
            );
        }
    }
    Ok(ModelEntry {
        name: name.to_string(),
        d_ft,
        d_lora,
        shapes: ModelShapes {
            batch: usize_field(m, "batch")?,
            eval_batch: usize_field(m, "eval_batch")?,
            seq: usize_field(cfg, "max_seq")?,
            k: usize_field(m, "k")?,
            n_classes: usize_field(cfg, "n_classes")?,
        },
        causal: cfg.get("causal").and_then(Json::as_bool).unwrap_or(false),
        pool: cfg
            .get("pool")
            .and_then(Json::as_str)
            .unwrap_or("cls")
            .to_string(),
        vocab: usize_field(cfg, "vocab")?,
        d_model: usize_field(cfg, "d_model")?,
        n_layers: usize_field(cfg, "n_layers")?,
        layout_ft,
        layout_lora,
        params_file: m
            .field("params")
            .map_err(|e| anyhow!("{e}"))?
            .field("file")
            .map_err(|e| anyhow!("{e}"))?
            .as_str()
            .unwrap_or_default()
            .to_string(),
        lora_init_file: m
            .field("lora_init")
            .map_err(|e| anyhow!("{e}"))?
            .field("file")
            .map_err(|e| anyhow!("{e}"))?
            .as_str()
            .unwrap_or_default()
            .to_string(),
        pretrain_accuracy: m
            .get("pretrain")
            .and_then(|p| p.get("pretrain_accuracy"))
            .and_then(Json::as_f64),
        init_accuracy: m
            .get("pretrain")
            .and_then(|p| p.get("init_accuracy"))
            .and_then(Json::as_f64),
        artifacts,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    const MINI: &str = r#"{
      "version": 3,
      "corpus": {"m": {"vocab": 64, "seq": 8, "n_classes": 2, "lexicon": 4,
                       "min_len": 4, "signal_min": 1, "signal_max": 2,
                       "contra": 0.1, "noise": 0.0, "seed": 7}},
      "models": {"m": {
        "config": {"vocab": 64, "d_model": 8, "n_layers": 1, "n_heads": 2,
                   "d_ff": 16, "max_seq": 8, "n_classes": 2, "causal": false,
                   "pool": "cls", "lora_rank": 2, "lora_scale": 2.0},
        "d_ft": 6, "d_lora": 4, "batch": 2, "eval_batch": 4, "k": 3,
        "layout_ft": [{"name": "a", "shape": [2, 3]}],
        "layout_lora": [{"name": "b", "shape": [4]}],
        "params": {"file": "m_params.bin", "len": 6, "sha256": ""},
        "lora_init": {"file": "m_lora_init.bin", "len": 4, "sha256": ""},
        "pretrain": {"pretrain_accuracy": 0.75},
        "artifacts": {"ft_loss": {"file": "m_ft_loss.hlo.txt", "bytes": 10}}
      }},
      "toy": {"d": 123, "n": 512}
    }"#;

    #[test]
    fn parses_mini_manifest() {
        let m = Manifest::from_json_text(MINI).unwrap();
        let e = m.model("m").unwrap();
        assert_eq!(e.d_ft, 6);
        assert_eq!(e.layout_ft[0].offset, 0);
        assert_eq!(e.layout_ft[0].len, 6);
        assert_eq!(e.shapes.k, 3);
        assert_eq!(e.artifact(TrainMode::Ft, "loss"), "m_ft_loss");
        assert_eq!(e.d_trainable(TrainMode::Lora), 4);
        assert_eq!(e.pretrain_accuracy, Some(0.75));
        assert_eq!(m.corpus("m").unwrap().vocab, 64);
        assert_eq!(m.toy_d, 123);
    }

    #[test]
    fn rejects_wrong_version() {
        let bad = MINI.replace("\"version\": 3", "\"version\": 999");
        assert!(Manifest::from_json_text(&bad).is_err());
    }

    #[test]
    fn rejects_layout_size_mismatch() {
        let bad = MINI.replace("\"d_ft\": 6", "\"d_ft\": 7");
        let err = Manifest::from_json_text(&bad).unwrap_err().to_string();
        assert!(err.contains("layout_ft"), "{err}");
    }

    #[test]
    fn unknown_model_is_error() {
        let m = Manifest::from_json_text(MINI).unwrap();
        assert!(m.model("nope").is_err());
    }
}
