//! # zo-ldsd
//!
//! Reproduction of *"Zero-Order Optimization for LLM Fine-Tuning via
//! Learnable Direction Sampling"* (ZO-LDSD) as a three-layer Rust + JAX +
//! Pallas system:
//!
//! * **L3 (this crate)** — the fine-tuning coordinator: direction-sampling
//!   policies ([`sampler`]), ZO gradient estimators and base optimizers
//!   ([`optim`]), oracle-budgeted training loops ([`train`]), the trial
//!   scheduler ([`coordinator`]), data pipeline ([`data`]), evaluation
//!   ([`eval`]) and reporting ([`report`]).
//! * **L2 (python/compile, build-time only)** — JAX transformer
//!   classifiers lowered to HLO text artifacts.
//! * **L1 (python/compile/kernels)** — Pallas kernels for the compute
//!   hot spots (fused attention, ZO perturbation axpy, LoRA matmul),
//!   lowered into the same artifacts.
//!
//! The [`runtime`] module loads the artifacts via PJRT; after
//! `make artifacts` the rust binary is fully self-contained — python never
//! runs on the training path.  Built without the `pjrt` feature (the
//! default), the runtime is an inert stub and the closed-form oracle stack
//! carries all tests and benches.
//!
//! Estimation is organised around the batched K-probe pipeline: estimators
//! `propose` a row-major K x d probe matrix, the oracle evaluates it in
//! one fused `loss_k` dispatch, and estimators `consume` the losses with
//! blocked combine kernels ([`tensor::probe_combine`] / [`tensor::axpy_k`]).
//! The whole O(K d) hot path runs shard-parallel on an
//! [`exec::ExecContext`] (`--threads` / `ZO_THREADS`), with results
//! bitwise identical for any worker count — shard boundaries, shard-order
//! reductions, and per-(step, shard) RNG substreams are all fixed by the
//! context's shard length, never by the schedule (DESIGN.md §9).
//!
//! Probe *storage* is abstracted behind [`probe::ProbeSource`]
//! (`--probe-storage materialized|streamed|auto`): the materialized path
//! holds the K x d matrix, while the streamed path regenerates probe
//! shards on demand from the samplers' RNG cells (MeZO-style seed
//! replay), cutting probe state from O(K d) to O(K · shard_len) per
//! worker with bitwise-identical trajectories (DESIGN.md §10).
//!
//! Runs are crash-safe and preemptible through the [`snapshot`]
//! subsystem (`--checkpoint-dir` / `--checkpoint-every` / `--resume`):
//! a snapshot is just params + optimizer moments + the LDSD policy mean
//! + a few cursors, and a run interrupted at any step resumes
//! bitwise-identically (DESIGN.md §11).
//!
//! Persistence routes through the content-addressed [`store`]
//! (`--store-dir` / `ZO_STORE_DIR`, `store gc|verify|ls` subcommands):
//! snapshot manifests reference blobs by SHA-256 hash so unchanged blobs
//! dedup across retained generations, completed grids warm-start by
//! canonical spec hash through `grid.lock.json`, and mark-and-sweep GC
//! rooted at manifests reclaims unreachable objects (DESIGN.md §16).
//!
//! Grids farm out over machines through the [`service`] subsystem
//! (`zo serve` / `zo work`): a coordinator leases spec-hash-keyed
//! trials and loss-evaluation shards to polling workers over a
//! vendored HTTP/1.1 + canonical-JSON wire (schema-versioned
//! [`coordinator::wire`]), workers sync store objects by hash, and the
//! merged report is byte-identical to the single-process run — leases
//! requeue on expiry, so a worker killed mid-trial never corrupts the
//! grid (DESIGN.md §17).
//!
//! The first *network* workload is the forward-only MLP classifier
//! ([`oracle::MlpOracle`] over the [`model::mlp`] core, `--oracle mlp`):
//! forward evaluation — not probe algebra — dominates its step, it rides
//! the full batched/streamed probe pipeline, and it trains on the
//! epoch-shuffled minibatch stream ([`data::TrainStream`]) whose batch
//! cursor rides in snapshots (DESIGN.md §12).
//! See README.md for the module map and DESIGN.md for design rationale.

#![warn(missing_docs)]

pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod exec;
pub mod jsonio;
pub mod metrics;
pub mod model;
pub mod optim;
pub mod oracle;
pub mod probe;
pub mod proptest;
pub mod report;
pub mod rng;
pub mod runtime;
pub mod sampler;
pub mod service;
pub mod snapshot;
pub mod store;
pub mod tensor;
pub mod train;
