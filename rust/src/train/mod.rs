//! Oracle-budgeted training loop (the paper's §5.1 protocol).
//!
//! Comparisons are *budget-fair*: every method gets the same number of
//! forward evaluations, so a K=1 central-difference baseline runs 3x the
//! iterations of a K=5 method.  The loop charges each step by the
//! estimator's actual oracle calls and stops when the budget is exhausted
//! (DESIGN.md §5).
//!
//! The loop drives the estimator through its two-phase `propose`/`consume`
//! flow: with [`ProbeDispatch::Batched`] (the default) the whole K-probe
//! batch is evaluated in one [`Oracle::loss_probes`] dispatch (the fused
//! `loss_k` on a materialized matrix, the streamed shard-replay evaluation
//! otherwise); [`ProbeDispatch::PerProbe`] issues K separate `loss_dir`
//! calls instead — same numbers, same accounting, kept for A/B throughput
//! benchmarking (`perf_hotpath`).  Probe storage itself is selected by
//! [`TrainConfig::probe_storage`] / `--probe-storage` / `ZO_PROBE_STORAGE`
//! (DESIGN.md §10).

mod schedule;

pub use schedule::{ConstantLr, CosineLr, LrSchedule};

/// Probe-storage selection re-exported where the run configuration lives.
pub use crate::probe::ProbeStorage;

/// Parameter-storage selection re-exported where the run configuration
/// lives (DESIGN.md §14).
pub use crate::tensor::ParamStoreMode;

/// GEMM-engine selection re-exported where the run configuration lives
/// (DESIGN.md §15).
pub use crate::tensor::GemmMode;

/// Checkpoint/resume policy re-exported where the run configuration lives.
pub use crate::snapshot::CheckpointConfig;

use anyhow::{bail, Result};

use crate::data::{Corpus, TrainStream};
use crate::eval::AccuracyEval;
use crate::exec::ExecContext;
use crate::optim::{
    BaseOptimizer, CentralK1Estimator, ForwardAvgEstimator, GradEstimator,
    LdsdEstimator,
};
use crate::oracle::Oracle;
use crate::sampler::{
    CoordinateSampler, GaussianSampler, LdsdConfig, LdsdSampler, SphereSampler,
};

/// Which direction distribution feeds the estimator.
#[derive(Clone, Debug)]
pub enum SamplerKind {
    /// v ~ N(0, I) (MeZO / ZO-SGD baseline).
    Gaussian,
    /// v uniform on the unit sphere.
    Sphere,
    /// one-hot coordinate directions scaled by sqrt(d).
    Coordinate,
    /// the paper's learnable policy v ~ N(mu, eps^2 I).
    Ldsd(LdsdConfig),
}

/// Which probe layout turns forwards into a gradient surrogate.
#[derive(Clone, Debug)]
pub enum EstimatorKind {
    /// central difference, one direction, 2 calls/step
    CentralK1(SamplerKind),
    /// forward-difference MC average over K directions, K+1 calls/step
    ForwardAvg {
        /// probe count K
        k: usize,
        /// direction distribution
        sampler: SamplerKind,
    },
    /// Algorithm 2: best-of-K selection + central difference + policy
    /// feedback, K+1 calls/step
    BestOfK {
        /// candidate count K
        k: usize,
        /// direction distribution
        sampler: SamplerKind,
    },
}

/// How the probe matrix of one estimation step reaches the oracle.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ProbeDispatch {
    /// One fused [`Oracle::loss_k`] dispatch for the whole K x d probe
    /// matrix (default; the PJRT oracle turns this into a single device
    /// dispatch, the closed-form oracles into one vectorized host pass).
    #[default]
    Batched,
    /// K separate `loss_dir` dispatches — the pre-batching behavior, kept
    /// for A/B benchmarking.  Identical numbers and oracle accounting.
    PerProbe,
}

impl ProbeDispatch {
    /// Parse from a CLI string ("batched" | "per-probe").
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "batched" => Ok(ProbeDispatch::Batched),
            "per-probe" | "per_probe" | "perprobe" => Ok(ProbeDispatch::PerProbe),
            other => bail!("unknown probe dispatch '{other}' (batched|per-probe)"),
        }
    }

    /// Label fragment for tables and bench rows.
    pub fn label(&self) -> &'static str {
        match self {
            ProbeDispatch::Batched => "batched",
            ProbeDispatch::PerProbe => "per_probe",
        }
    }
}

impl SamplerKind {
    /// True if this direction distribution supports seed replay (the
    /// streamed probe path).  The sphere sampler normalizes whole rows, so
    /// it cannot regenerate elements independently and stays materialized.
    pub fn supports_replay(&self) -> bool {
        !matches!(self, SamplerKind::Sphere)
    }
}

impl EstimatorKind {
    /// The direction distribution this estimator draws from.
    pub fn sampler_kind(&self) -> &SamplerKind {
        match self {
            EstimatorKind::CentralK1(s) => s,
            EstimatorKind::ForwardAvg { sampler, .. } => sampler,
            EstimatorKind::BestOfK { sampler, .. } => sampler,
        }
    }

    /// Oracle calls one step of this estimator consumes.
    pub fn calls_per_step(&self) -> u64 {
        match self {
            EstimatorKind::CentralK1(_) => 2,
            EstimatorKind::ForwardAvg { k, .. } => *k as u64 + 1,
            EstimatorKind::BestOfK { k, .. } => *k as u64 + 1,
        }
    }

    /// Human-readable label ("bestofk5/ldsd" etc.).
    pub fn label(&self) -> String {
        match self {
            EstimatorKind::CentralK1(s) => format!("central_k1/{}", sampler_label(s)),
            EstimatorKind::ForwardAvg { k, sampler } => {
                format!("forward_avg_k{k}/{}", sampler_label(sampler))
            }
            EstimatorKind::BestOfK { k, sampler } => {
                format!("bestofk{k}/{}", sampler_label(sampler))
            }
        }
    }
}

fn sampler_label(s: &SamplerKind) -> &'static str {
    match s {
        SamplerKind::Gaussian => "gaussian",
        SamplerKind::Sphere => "sphere",
        SamplerKind::Coordinate => "coordinate",
        SamplerKind::Ldsd(_) => "ldsd",
    }
}

fn build_sampler(kind: &SamplerKind, d: usize, seed: u64) -> crate::probe::BoxedSampler {
    match kind {
        SamplerKind::Gaussian => Box::new(GaussianSampler::new(d, seed)),
        SamplerKind::Sphere => Box::new(SphereSampler::new(d, seed)),
        SamplerKind::Coordinate => Box::new(CoordinateSampler::new(d, seed)),
        SamplerKind::Ldsd(cfg) => Box::new(LdsdSampler::new(d, seed, cfg.clone())),
    }
}

// DirectionSampler must be object-safe for the boxed path; estimators are
// generic, so we wrap the boxed sampler in a forwarding impl.
impl crate::sampler::DirectionSampler for crate::probe::BoxedSampler {
    fn sample(&mut self, dirs: &mut [f32], k: usize) {
        (**self).sample(dirs, k)
    }
    fn set_exec(&mut self, ctx: ExecContext) {
        (**self).set_exec(ctx)
    }
    fn observe(&mut self, dirs: &[f32], losses: &[f64], k: usize) {
        (**self).observe(dirs, losses, k)
    }
    fn supports_replay(&self) -> bool {
        (**self).supports_replay()
    }
    fn advance_step(&mut self) {
        (**self).advance_step()
    }
    fn fill_row_range(
        &self,
        k: usize,
        row: usize,
        col0: usize,
        out: &mut [f32],
        scratch: &mut [f32],
    ) {
        (**self).fill_row_range(k, row, col0, out, scratch)
    }
    fn observe_replay(&mut self, losses: &[f64], k: usize) {
        (**self).observe_replay(losses, k)
    }
    fn step_label(&self) -> u64 {
        (**self).step_label()
    }
    fn restore_state(
        &mut self,
        step: u64,
        policy_mean: Option<&[f32]>,
    ) -> anyhow::Result<()> {
        (**self).restore_state(step, policy_mean)
    }
    fn dim(&self) -> usize {
        (**self).dim()
    }
    fn state_bytes(&self) -> usize {
        (**self).state_bytes()
    }
    fn name(&self) -> &str {
        (**self).name()
    }
    fn policy_mean(&self) -> Option<&[f32]> {
        (**self).policy_mean()
    }
}

/// Instantiate the estimator described by `kind` for dimensionality `d`,
/// wired to the given shard-parallel execution context (the context
/// cascades to the estimator's probe source and sampler) and the given
/// probe storage ([`ProbeStorage::Auto`] resolves by memory budget).
pub fn build_estimator(
    kind: &EstimatorKind,
    d: usize,
    tau: f32,
    seed: u64,
    exec: &ExecContext,
    storage: ProbeStorage,
) -> Result<Box<dyn GradEstimator + Send>> {
    let mut est: Box<dyn GradEstimator + Send> = match kind {
        EstimatorKind::CentralK1(s) => Box::new(CentralK1Estimator::with_storage(
            build_sampler(s, d, seed),
            tau,
            storage,
        )?),
        EstimatorKind::ForwardAvg { k, sampler } => Box::new(
            ForwardAvgEstimator::with_storage(build_sampler(sampler, d, seed), tau, *k, storage)?,
        ),
        EstimatorKind::BestOfK { k, sampler } => Box::new(LdsdEstimator::with_storage(
            build_sampler(sampler, d, seed),
            tau,
            *k,
            storage,
        )?),
    };
    est.set_exec(exec.clone());
    Ok(est)
}

/// The parameter-storage mode a config *requests* before any oracle
/// capability check, under the uniform CONFIGURED > ENV precedence
/// contract: an explicit off-default config beats `ZO_PARAM_STORE`; the
/// env forces only unconfigured (f32-default) runs.  Shared between the
/// trainer's resolution ([`Trainer::with_exec`]) and the canonical spec
/// hash ([`crate::coordinator::spec_hash`]) so the hash always names the
/// store the run will actually use (quantization changes the
/// trajectory, so a false cache hit would serve wrong numbers).
pub fn requested_param_store(cfg: &TrainConfig) -> ParamStoreMode {
    if cfg.param_store != ParamStoreMode::F32 {
        return cfg.param_store;
    }
    std::env::var("ZO_PARAM_STORE")
        .ok()
        .and_then(|s| ParamStoreMode::parse(&s))
        .unwrap_or(ParamStoreMode::F32)
}

/// Deterministic epoch shuffling of a finite training prefix
/// ([`crate::data::EpochShuffle`]): each epoch visits the first `n_train`
/// corpus examples once, in a per-epoch pseudorandom order keyed by the
/// run seed.  `None` keeps the original sequential disjoint-window
/// stream.  The run's batch cursor rides in snapshots, so a resumed
/// shuffled run sees the identical batch sequence (DESIGN.md §12).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShuffleSpec {
    /// Corpus examples per epoch (must stay below the held-out range).
    pub n_train: u64,
}

/// Everything one training run needs (estimator x optimizer x budget).
#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// Probe layout + direction distribution.
    pub estimator: EstimatorKind,
    /// Base-optimizer name (see `optimizers_by_name`).
    pub optimizer: String,
    /// Base learning rate for the x-update.
    pub lr: f32,
    /// Finite-difference scale tau.
    pub tau: f32,
    /// Total forward-evaluation budget (the §5.1 fairness unit).
    pub budget: u64,
    /// Evaluate every this many oracle calls (0 = only at the end).
    pub eval_every: u64,
    /// Test batches per evaluation point.
    pub eval_batches: usize,
    /// Cosine-decay the learning rate over the planned step count.
    pub cosine_schedule: bool,
    /// Seed for samplers/estimators.
    pub seed: u64,
    /// Fused vs per-probe oracle dispatch (numerically equivalent).
    pub probe_dispatch: ProbeDispatch,
    /// Probe-matrix storage: materialized K x d buffer, streamed seed
    /// replay, or auto-selection by memory budget.  Bitwise-identical
    /// trajectories either way (DESIGN.md §10); `ZO_PROBE_STORAGE`
    /// overrides for whole-suite forcing.
    pub probe_storage: ProbeStorage,
    /// Crash-safe checkpoint/resume policy (DESIGN.md §11).  The default
    /// disables checkpointing; a resumed run is bitwise identical to the
    /// uninterrupted one.
    pub checkpoint: CheckpointConfig,
    /// Minibatch ordering: `None` = sequential disjoint windows (the
    /// original stream), `Some` = deterministic epoch shuffling of a
    /// finite prefix (the MLP workload's default; DESIGN.md §12).
    pub shuffle: Option<ShuffleSpec>,
    /// Resident parameter storage: full-precision f32 (default) or a
    /// quantized (f16/int8) store evaluated through fused dequant kernels
    /// (DESIGN.md §14).  `ZO_PARAM_STORE` overrides for whole-suite
    /// forcing; quantized modes need a supporting oracle
    /// ([`crate::oracle::Oracle::supports_param_store`]).
    pub param_store: ParamStoreMode,
    /// Model-forward GEMM engine: the blocked batched kernel (default) or
    /// the row-at-a-time reference loop.  Bit-identical trajectories
    /// either way (the §15 tiling contract); `ZO_GEMM` overrides for
    /// whole-suite forcing.
    pub gemm: GemmMode,
}

impl TrainConfig {
    /// Table 1 row "Gaussian, 2 forwards, more iterations".
    pub fn gaussian_2fwd(optimizer: &str, lr: f32, budget: u64) -> Self {
        Self {
            estimator: EstimatorKind::CentralK1(SamplerKind::Gaussian),
            optimizer: optimizer.into(),
            lr,
            tau: 1e-3,
            budget,
            eval_every: 0,
            eval_batches: 8,
            cosine_schedule: true,
            seed: 0,
            probe_dispatch: ProbeDispatch::Batched,
            probe_storage: ProbeStorage::Auto,
            checkpoint: CheckpointConfig::default(),
            shuffle: None,
            param_store: ParamStoreMode::F32,
            gemm: GemmMode::Blocked,
        }
    }

    /// Table 1 row "Gaussian, 6 forwards, same iterations" (K = 5).
    pub fn gaussian_6fwd(optimizer: &str, lr: f32, budget: u64) -> Self {
        Self {
            estimator: EstimatorKind::ForwardAvg { k: 5, sampler: SamplerKind::Gaussian },
            optimizer: optimizer.into(),
            lr,
            tau: 1e-3,
            budget,
            eval_every: 0,
            eval_batches: 8,
            cosine_schedule: true,
            seed: 0,
            probe_dispatch: ProbeDispatch::Batched,
            probe_storage: ProbeStorage::Auto,
            checkpoint: CheckpointConfig::default(),
            shuffle: None,
            param_store: ParamStoreMode::F32,
            gemm: GemmMode::Blocked,
        }
    }

    /// Table 1 row "Algorithm 2" (K = 5, eps = 1, gamma_mu = 1e-3 per §A.2).
    /// `renormalize` keeps ||mu|| = 1 — the paper's §3.5 "natural design
    /// choice"; without it ||mu|| grows without bound and inflates the
    /// effective x-step (we ablate this in fig3/examples/ablations).
    pub fn algorithm2(optimizer: &str, lr: f32, budget: u64) -> Self {
        Self {
            estimator: EstimatorKind::BestOfK {
                k: 5,
                sampler: SamplerKind::Ldsd(LdsdConfig {
                    eps: 1.0,
                    gamma_mu: 1e-3,
                    renormalize: true,
                    ..Default::default()
                }),
            },
            optimizer: optimizer.into(),
            lr,
            tau: 1e-3,
            budget,
            eval_every: 0,
            eval_batches: 8,
            cosine_schedule: true,
            seed: 0,
            probe_dispatch: ProbeDispatch::Batched,
            probe_storage: ProbeStorage::Auto,
            checkpoint: CheckpointConfig::default(),
            shuffle: None,
            param_store: ParamStoreMode::F32,
            gemm: GemmMode::Blocked,
        }
    }
}

/// Result of one training run.
#[derive(Clone, Debug, Default)]
pub struct TrainOutcome {
    /// (oracle calls, training-loss proxy) per step
    pub loss_curve: Vec<(u64, f64)>,
    /// (oracle calls, test accuracy) at each eval point
    pub acc_curve: Vec<(u64, f64)>,
    /// Test accuracy at the end of the run.
    pub final_accuracy: f64,
    /// Best test accuracy seen at any eval point.
    pub best_accuracy: f64,
    /// Optimizer steps taken.
    pub steps: u64,
    /// Forward evaluations consumed.
    pub oracle_calls: u64,
    /// Wall-clock duration of the run.
    pub wall_seconds: f64,
    /// Human-readable method label.
    pub label: String,
    /// True when the budget was exhausted; false when the session halted
    /// early ([`CheckpointConfig::max_run_steps`] cooperative preemption —
    /// resume the run to continue it).
    pub completed: bool,
}

/// Mid-run cursors captured by snapshots: everything [`Trainer::run`]
/// needs to continue a run besides the parameters, optimizer moments and
/// sampler state.  All counters span sessions (a resumed run picks up
/// where the snapshot stopped).
#[derive(Clone, Debug, Default)]
pub struct RunProgress {
    /// Optimizer steps taken so far.
    pub step: u64,
    /// Oracle calls consumed so far.
    pub used: u64,
    /// Training examples consumed so far — the data-pipeline cursor the
    /// minibatch stream is addressed by ([`crate::data::TrainStream`]).
    /// Restoring it is all a resumed run needs to replay the identical
    /// batch sequence, shuffled or not (DESIGN.md §12).
    pub data_cursor: u64,
    /// Next evaluation threshold (in oracle calls).
    pub next_eval: u64,
    /// (oracle calls, training-loss proxy) per step so far.
    pub loss_curve: Vec<(u64, f64)>,
    /// (oracle calls, test accuracy) per eval point so far.
    pub acc_curve: Vec<(u64, f64)>,
    /// Best test accuracy seen at any eval point so far.
    pub best_accuracy: f64,
}

/// The training loop: estimator x optimizer over a corpus stream, charged
/// by oracle calls.
pub struct Trainer<O: Oracle> {
    /// The run configuration (immutable during the run).
    pub cfg: TrainConfig,
    oracle: O,
    stream: TrainStream,
    estimator: Box<dyn GradEstimator + Send>,
    optimizer: Box<dyn BaseOptimizer + Send>,
    g: Vec<f32>,
    /// Probe-loss buffer reused across steps (no per-step allocation).
    probe_losses: Vec<f64>,
    /// Dequantized-parameter buffer reused by eval points (the oracle may
    /// keep no resident f32 image; see [`TrainConfig::param_store`]).
    ptmp: Vec<f32>,
    /// Resolved parameter-storage mode (config + `ZO_PARAM_STORE`), part
    /// of the snapshot fingerprint.
    param_store: ParamStoreMode,
    /// Resolved GEMM engine (config + `ZO_GEMM`), part of the snapshot
    /// fingerprint (the modes are bitwise identical, but the fingerprint
    /// records which engine produced the trajectory; DESIGN.md §15).
    gemm: GemmMode,
    /// Cross-session run cursors (what snapshots capture and restore).
    progress: RunProgress,
}

impl<O: Oracle> Trainer<O> {
    /// Wire up estimator + optimizer for `oracle`'s dimensionality, with
    /// the execution context taken from the environment
    /// ([`ExecContext::from_env`]; `ZO_THREADS` overrides).  Results are
    /// bitwise identical for any thread count (DESIGN.md §9).
    pub fn new(cfg: TrainConfig, oracle: O, corpus: Corpus) -> Result<Self> {
        Self::with_exec(cfg, oracle, corpus, ExecContext::from_env())
    }

    /// [`Trainer::new`] with an explicit shard-parallel execution context:
    /// the context cascades to the estimator, its sampler, and the oracle's
    /// vectorized evaluation paths.
    pub fn with_exec(
        cfg: TrainConfig,
        mut oracle: O,
        corpus: Corpus,
        exec: ExecContext,
    ) -> Result<Self> {
        let d = oracle.dim();
        let storage = Self::resolve_storage(&cfg, &oracle)?;
        let param_store = Self::resolve_param_store(&cfg, &oracle)?;
        let gemm = Self::resolve_gemm(&cfg)?;
        crate::tensor::gemm::set_run_mode(Some(gemm));
        let estimator = build_estimator(&cfg.estimator, d, cfg.tau, cfg.seed, &exec, storage)?;
        let optimizer = crate::optim::optimizers_by_name(&cfg.optimizer, d)?;
        oracle.set_exec(exec);
        oracle.set_param_store(param_store)?;
        // the minibatch ordering: sequential disjoint windows, or the
        // deterministic epoch shuffle keyed by the run seed
        let stream = match &cfg.shuffle {
            None => TrainStream::sequential(corpus),
            Some(s) => TrainStream::shuffled(corpus, s.n_train, cfg.seed)?,
        };
        let progress = RunProgress { next_eval: cfg.eval_every, ..Default::default() };
        Ok(Self {
            cfg,
            oracle,
            stream,
            estimator,
            optimizer,
            g: vec![0.0; d],
            probe_losses: Vec::new(),
            ptmp: Vec::new(),
            param_store,
            gemm,
            progress,
        })
    }

    /// Resolve the run's parameter storage under the uniform
    /// CONFIGURED > ENV precedence contract (DESIGN.md §17): an explicit
    /// off-default config (`--param-store f16|int8`) beats the
    /// `ZO_PARAM_STORE` environment override; the env forces only
    /// unconfigured (f32-default) runs, which is what CI's suite-wide
    /// forcing arms need.  A quantized mode needs a supporting oracle
    /// ([`Oracle::supports_param_store`]): when the request came from the
    /// environment the run quietly keeps f32 (so suite-wide forcing skips
    /// the closed-form substrates), while an explicitly configured
    /// quantized mode errors instead of silently widening.  An invalid
    /// env value always errors, even when the config wins — a typo must
    /// fail loudly.
    fn resolve_param_store(cfg: &TrainConfig, oracle: &O) -> Result<ParamStoreMode> {
        if let Ok(s) = std::env::var("ZO_PARAM_STORE") {
            if ParamStoreMode::parse(&s).is_none() {
                bail!("ZO_PARAM_STORE='{s}' (expected f32|f16|int8)");
            }
        }
        let configured = cfg.param_store != ParamStoreMode::F32;
        let requested = requested_param_store(cfg);
        if requested == ParamStoreMode::F32 || oracle.supports_param_store() {
            return Ok(requested);
        }
        if !configured {
            eprintln!(
                "ZO_PARAM_STORE={}: oracle '{}' keeps f32 parameter storage \
                 (quantized stores unsupported)",
                requested.label(),
                oracle.name()
            );
            return Ok(ParamStoreMode::F32);
        }
        bail!(
            "oracle '{}' does not support --param-store {} (f32 only)",
            oracle.name(),
            requested.label()
        )
    }

    /// Resolve the run's GEMM engine under the uniform CONFIGURED > ENV
    /// precedence contract: an explicit off-default config
    /// (`--gemm reference`) beats the `ZO_GEMM` environment override, so
    /// A/B rows that pin the reference engine stay pinned under CI's
    /// suite-forcing arms; the env forces only unconfigured
    /// (blocked-default) runs.  An invalid env value always errors.  No
    /// capability check is needed — both engines are plain CPU paths
    /// every oracle supports, and they produce identical bits
    /// (DESIGN.md §15), so the choice only moves throughput.
    fn resolve_gemm(cfg: &TrainConfig) -> Result<GemmMode> {
        let env = match std::env::var("ZO_GEMM") {
            Ok(s) => match GemmMode::parse(&s) {
                Some(m) => Some(m),
                None => bail!("ZO_GEMM='{s}' (expected reference|blocked)"),
            },
            Err(_) => None,
        };
        if cfg.gemm != GemmMode::Blocked {
            return Ok(cfg.gemm);
        }
        Ok(env.unwrap_or(cfg.gemm))
    }

    /// Resolve the run's probe storage under the uniform CONFIGURED > ENV
    /// precedence contract: an explicit off-default config
    /// (`--probe-storage materialized|streamed`) beats the
    /// `ZO_PROBE_STORAGE` environment override — so equivalence tests
    /// that pin one path stay pinned under CI's suite-forcing arms — and
    /// the env forces only unconfigured (`Auto`) runs.  Streaming needs
    /// batched dispatch + a streaming-capable oracle + a seed-replay
    /// sampler.  When those preconditions fail, an env- or auto-derived
    /// `streamed` quietly falls back to materialized (the two are bitwise
    /// identical, so the run is still correct); an explicitly configured
    /// `streamed` errors instead so a CLI user is not silently handed the
    /// path they opted out of.  An invalid env value panics in
    /// [`ProbeStorage::from_env`] — a typo must fail loudly.
    fn resolve_storage(cfg: &TrainConfig, oracle: &O) -> Result<ProbeStorage> {
        let env = ProbeStorage::from_env();
        let configured = cfg.probe_storage != ProbeStorage::Auto;
        let requested = if configured {
            cfg.probe_storage
        } else {
            env.unwrap_or(ProbeStorage::Auto)
        };
        let streaming_ok = cfg.probe_dispatch == ProbeDispatch::Batched
            && oracle.supports_streamed_probes()
            && cfg.estimator.sampler_kind().supports_replay();
        match requested {
            ProbeStorage::Streamed if !streaming_ok => {
                if !configured {
                    // the request came from the environment: quiet,
                    // bitwise-identical fallback
                    Ok(ProbeStorage::Materialized)
                } else {
                    bail!(
                        "probe storage 'streamed' needs batched dispatch ({}), a \
                         streaming-capable oracle ({}: {}), and a seed-replay sampler \
                         ({}: {})",
                        cfg.probe_dispatch.label(),
                        oracle.name(),
                        oracle.supports_streamed_probes(),
                        sampler_label(cfg.estimator.sampler_kind()),
                        cfg.estimator.sampler_kind().supports_replay(),
                    )
                }
            }
            ProbeStorage::Auto if !streaming_ok => Ok(ProbeStorage::Materialized),
            other => Ok(other),
        }
    }

    /// Read access to the oracle (budget inspection).
    pub fn oracle(&self) -> &O {
        &self.oracle
    }

    /// Mutable access to the oracle (checkpoint restore).
    pub fn oracle_mut(&mut self) -> &mut O {
        &mut self.oracle
    }

    /// The estimator driving this run.
    pub fn estimator(&self) -> &dyn GradEstimator {
        self.estimator.as_ref()
    }

    /// The cross-session run cursors (what snapshots capture).
    pub fn progress(&self) -> &RunProgress {
        &self.progress
    }

    /// The configuration identity snapshots of this run are stamped with
    /// (and validated against on restore).
    pub fn fingerprint(&self) -> crate::snapshot::SnapshotFingerprint {
        // the data ordering walks into the trajectory, so it is part of
        // the identity a snapshot may be restored under
        let mut label = format!("{}+{}", self.cfg.estimator.label(), self.cfg.optimizer);
        if let Some(s) = &self.cfg.shuffle {
            label.push_str(&format!("+shuffle{}", s.n_train));
        }
        // so does the parameter-storage mode: a quantized run walks a
        // different (requantized) trajectory than the f32 run
        if self.param_store != ParamStoreMode::F32 {
            label.push_str(&format!("+{}", self.param_store.label()));
        }
        // the GEMM engine does NOT change the trajectory (the blocked
        // kernel is bitwise identical to the reference loop), but a
        // non-default engine is still recorded so a restored run knows
        // which path produced its numbers
        if self.gemm != GemmMode::Blocked {
            label.push_str("+gemmref");
        }
        crate::snapshot::SnapshotFingerprint {
            label,
            seed: self.cfg.seed,
            budget: self.cfg.budget,
            dim: self.oracle.dim(),
        }
    }

    /// Capture a full training snapshot at the current step boundary:
    /// parameters, optimizer moments, the sampler's RNG step label +
    /// policy mean, and the run cursors.  Restoring it (on this or a
    /// freshly built trainer with the same configuration) and continuing
    /// is bitwise identical to never having stopped — probe directions
    /// are pure functions of (seed, step, shard) RNG cells, so no probe
    /// state needs saving (DESIGN.md §11).
    pub fn snapshot(&self) -> crate::snapshot::TrainerSnapshot {
        let sampler = self.estimator.probes().sampler();
        crate::snapshot::TrainerSnapshot {
            version: crate::snapshot::SNAPSHOT_VERSION,
            fingerprint: self.fingerprint(),
            step: self.progress.step,
            oracle_calls_used: self.progress.used,
            next_eval: self.progress.next_eval,
            data_cursor: self.progress.data_cursor,
            sampler_step: sampler.step_label(),
            best_accuracy: self.progress.best_accuracy,
            params: {
                // dequantized image: restore requantizes it, which is
                // exact on the dequant grid (DESIGN.md §14)
                let mut p = Vec::new();
                self.oracle.params_into(&mut p);
                p
            },
            optimizer: self.optimizer.state(),
            policy_mean: sampler.policy_mean().map(|m| m.to_vec()),
            loss_curve: self.progress.loss_curve.clone(),
            acc_curve: self.progress.acc_curve.clone(),
        }
    }

    /// Restore a snapshot captured by [`Trainer::snapshot`] onto this
    /// (freshly built, not-yet-run) trainer.  Validates the snapshot's
    /// fingerprint against this run's configuration — resuming under a
    /// different estimator/optimizer/seed/budget is a hard error, not a
    /// silent divergence.
    pub fn restore(&mut self, snap: &crate::snapshot::TrainerSnapshot) -> Result<()> {
        if snap.version != crate::snapshot::SNAPSHOT_VERSION {
            bail!(
                "snapshot version {} (this build reads {})",
                snap.version,
                crate::snapshot::SNAPSHOT_VERSION
            );
        }
        let fp = self.fingerprint();
        if snap.fingerprint != fp {
            bail!(
                "snapshot fingerprint mismatch: snapshot is {:?}, this run is {:?}",
                snap.fingerprint,
                fp
            );
        }
        if snap.params.len() != self.oracle.dim() {
            bail!(
                "snapshot params hold {} f32, oracle wants {}",
                snap.params.len(),
                self.oracle.dim()
            );
        }
        let params = &snap.params;
        self.oracle.update_params(&mut |x| x.copy_from_slice(params))?;
        self.optimizer.load_state(&snap.optimizer)?;
        self.estimator
            .probes_mut()
            .sampler_mut()
            .restore_state(snap.sampler_step, snap.policy_mean.as_deref())?;
        self.progress = RunProgress {
            step: snap.step,
            used: snap.oracle_calls_used,
            next_eval: snap.next_eval,
            data_cursor: snap.data_cursor,
            loss_curve: snap.loss_curve.clone(),
            acc_curve: snap.acc_curve.clone(),
            best_accuracy: snap.best_accuracy,
        };
        Ok(())
    }

    /// Write a snapshot of the current step boundary into the configured
    /// checkpoint directory (no-op when none is configured).  Blobs land
    /// in the resolved content-addressed store
    /// ([`crate::snapshot::resolve_store_dir`]), the step directory only
    /// holds the manifest.
    fn write_snapshot_now(&self) -> Result<()> {
        if let Some(dir) = &self.cfg.checkpoint.dir {
            let store = crate::snapshot::open_store(&self.cfg.checkpoint)
                .expect("checkpoint dir set implies a resolvable store");
            let snap = self.snapshot();
            crate::snapshot::write_snapshot(std::path::Path::new(dir), &store, &snap)?;
        }
        Ok(())
    }

    /// One estimation step under the configured probe dispatch.  Both
    /// paths stage probe losses in the trainer's reusable buffer; on the
    /// materialized path the per-step hot path allocates nothing after
    /// warmup, while the streamed path allocates its bounded per-worker
    /// shard scratch per dispatch (the deliberate O(K · shard_len) trade
    /// of DESIGN.md §10).
    fn estimate_step(&mut self) -> Result<crate::optim::Estimate> {
        match self.cfg.probe_dispatch {
            ProbeDispatch::Batched => self.estimator.estimate_with(
                &mut self.oracle,
                &mut self.g,
                &mut self.probe_losses,
            ),
            ProbeDispatch::PerProbe => {
                let d = self.oracle.dim();
                {
                    let batch = self.estimator.propose()?;
                    // per-probe dispatch reads row slices, so it requires
                    // a materialized source — resolve_storage guarantees
                    // streamed is never paired with it
                    let dirs = match batch.dirs {
                        Some(dirs) => dirs,
                        None => bail!(
                            "per-probe dispatch needs a materialized probe matrix \
                             (probe storage is streamed)"
                        ),
                    };
                    self.probe_losses.clear();
                    for i in 0..batch.k {
                        let l = self
                            .oracle
                            .loss_dir(&dirs[i * d..(i + 1) * d], batch.tau)?;
                        self.probe_losses.push(l);
                    }
                }
                self.estimator
                    .consume(&mut self.oracle, &self.probe_losses, &mut self.g)
            }
        }
    }

    /// Run until the oracle budget is exhausted (or the session's
    /// [`CheckpointConfig::max_run_steps`] preemption point).  `eval`
    /// computes test accuracy from the trainable vector (None for
    /// closed-form tests).
    ///
    /// With [`CheckpointConfig::resume`] set and a not-yet-started
    /// trainer, the newest valid snapshot in the checkpoint directory is
    /// restored first; with [`CheckpointConfig::every`] > 0, a snapshot
    /// is written every that-many steps.  A run interrupted at any step
    /// and resumed produces a bitwise-identical [`TrainOutcome`] (losses,
    /// accuracy curve, final parameters) to the uninterrupted run —
    /// `tests/checkpoint_resume.rs` pins this across thread counts and
    /// probe-storage modes.
    pub fn run(&mut self, eval: Option<&dyn AccuracyEval>) -> Result<TrainOutcome> {
        let t0 = std::time::Instant::now();
        if self.cfg.checkpoint.resume && self.progress.step == 0 {
            if let Some(dir) = self.cfg.checkpoint.dir.clone() {
                // legacy (pre-store) snapshot trees load fine through the
                // same call: v2 manifests never touch the store
                let store = crate::snapshot::open_store(&self.cfg.checkpoint);
                if let Some(snap) =
                    crate::snapshot::load_latest(std::path::Path::new(&dir), store.as_ref())
                {
                    self.restore(&snap)?;
                }
            }
        }
        let calls_per_step = self.estimator.calls_per_step();
        // the schedule derives from the *configured* budget, so a resumed
        // run sees the identical lr(step) function
        let planned_steps = (self.cfg.budget / calls_per_step.max(1)).max(1);
        let schedule: Box<dyn LrSchedule> = if self.cfg.cosine_schedule {
            Box::new(CosineLr::new(self.cfg.lr, planned_steps))
        } else {
            Box::new(ConstantLr(self.cfg.lr))
        };

        let label = format!("{}+{}", self.cfg.estimator.label(), self.cfg.optimizer);
        // all accounting is relative: a fresh oracle starts at 0 calls, a
        // resumed session carries the snapshot's used-count as its base,
        // so curve entries are identical either way
        let start_calls = self.oracle.oracle_calls();
        let base_used = self.progress.used;
        let max_run_steps = self.cfg.checkpoint.max_run_steps;
        let mut session_steps = 0u64;
        let mut halted = false;

        loop {
            let used = base_used + (self.oracle.oracle_calls() - start_calls);
            if used + calls_per_step > self.cfg.budget {
                break;
            }
            if max_run_steps > 0 && session_steps >= max_run_steps {
                halted = true;
                break;
            }
            let step = self.progress.step;
            let bsz = self.train_batch_size();
            // the stream is addressed by the batch cursor (examples
            // consumed), which snapshots carry — a resumed run replays
            // the identical batch sequence, shuffled or sequential
            let batch = self.stream.train_batch(self.progress.data_cursor, bsz);
            self.oracle.set_batch(&batch)?;
            let est = self.estimate_step()?;
            let lr = schedule.lr(step);
            // apply the base-optimizer update through the oracle so any
            // device-resident copy is invalidated exactly once per step
            let g = &self.g;
            let opt = &mut self.optimizer;
            self.oracle.update_params(&mut |x| opt.step(x, g, lr))?;
            let used_now = base_used + (self.oracle.oracle_calls() - start_calls);
            self.progress.loss_curve.push((used_now, est.loss));
            self.progress.step += 1;
            self.progress.data_cursor += bsz as u64;
            session_steps += 1;

            if self.cfg.eval_every > 0 && used_now >= self.progress.next_eval {
                self.progress.next_eval += self.cfg.eval_every;
                if let Some(ev) = eval {
                    self.oracle.params_into(&mut self.ptmp);
                    let acc = ev.accuracy(
                        &self.ptmp,
                        self.stream.corpus(),
                        self.cfg.eval_batches,
                    )?;
                    self.progress.acc_curve.push((used_now, acc));
                    self.progress.best_accuracy =
                        self.progress.best_accuracy.max(acc);
                }
            }

            let every = self.cfg.checkpoint.every;
            if every > 0 && self.progress.step % every == 0 {
                self.progress.used = used_now;
                self.write_snapshot_now()?;
            }
        }

        self.progress.used = base_used + (self.oracle.oracle_calls() - start_calls);
        if halted {
            // preemption point: persist the boundary so nothing between
            // snapshot cadences is lost
            self.write_snapshot_now()?;
        }

        let mut out = TrainOutcome {
            label,
            loss_curve: self.progress.loss_curve.clone(),
            acc_curve: self.progress.acc_curve.clone(),
            best_accuracy: self.progress.best_accuracy,
            steps: self.progress.step,
            oracle_calls: self.progress.used,
            completed: !halted,
            ..Default::default()
        };
        if !halted {
            if let Some(ev) = eval {
                self.oracle.params_into(&mut self.ptmp);
                let acc = ev.accuracy(
                    &self.ptmp,
                    self.stream.corpus(),
                    self.cfg.eval_batches,
                )?;
                out.acc_curve.push((self.progress.used, acc));
                out.final_accuracy = acc;
                out.best_accuracy = out.best_accuracy.max(acc);
            }
        }
        out.wall_seconds = t0.elapsed().as_secs_f64();
        Ok(out)
    }

    fn train_batch_size(&self) -> usize {
        8 // matches BuildPlan.batch; PJRT oracles validate on set_batch
    }
}

/// Small helper so train doesn't depend on optim internals.
pub use crate::optim::Estimate;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::CorpusSpec;
    use crate::oracle::QuadraticOracle;

    fn mini_corpus() -> Corpus {
        Corpus::new(CorpusSpec::default_mini()).unwrap()
    }

    fn quad(d: usize) -> QuadraticOracle {
        QuadraticOracle::new(vec![1.0; d], vec![1.0; d], vec![0.0; d])
    }

    #[test]
    fn budget_respected_exactly() {
        let cfg = TrainConfig {
            eval_every: 0,
            cosine_schedule: false,
            ..TrainConfig::algorithm2("zo_sgd_plain", 0.05, 61)
        };
        let mut t = Trainer::new(cfg, quad(16), mini_corpus()).unwrap();
        let out = t.run(None).unwrap();
        // 61 budget / 6 calls-per-step = 10 steps, 60 calls
        assert_eq!(out.steps, 10);
        assert_eq!(out.oracle_calls, 60);
    }

    #[test]
    fn fixed_budget_means_more_steps_for_cheaper_estimator() {
        let budget = 120;
        let mk = |est: EstimatorKind| TrainConfig {
            estimator: est,
            optimizer: "zo_sgd_plain".into(),
            lr: 0.02,
            tau: 1e-3,
            budget,
            eval_every: 0,
            eval_batches: 1,
            cosine_schedule: false,
            seed: 1,
            probe_dispatch: ProbeDispatch::Batched,
            probe_storage: ProbeStorage::Auto,
            checkpoint: CheckpointConfig::default(),
            shuffle: None,
            param_store: ParamStoreMode::F32,
            gemm: GemmMode::Blocked,
        };
        let mut t2 = Trainer::new(
            mk(EstimatorKind::CentralK1(SamplerKind::Gaussian)),
            quad(8),
            mini_corpus(),
        )
        .unwrap();
        let mut t6 = Trainer::new(
            mk(EstimatorKind::ForwardAvg { k: 5, sampler: SamplerKind::Gaussian }),
            quad(8),
            mini_corpus(),
        )
        .unwrap();
        let o2 = t2.run(None).unwrap();
        let o6 = t6.run(None).unwrap();
        assert_eq!(o2.steps, 60);
        assert_eq!(o6.steps, 20);
    }

    #[test]
    fn quadratic_loss_decreases_under_algorithm2() {
        let cfg = TrainConfig {
            cosine_schedule: false,
            ..TrainConfig::algorithm2("zo_sgd_plain", 0.05, 3000)
        };
        let mut t = Trainer::new(cfg, quad(24), mini_corpus()).unwrap();
        let out = t.run(None).unwrap();
        let first = out.loss_curve.first().unwrap().1;
        let last = out.loss_curve.last().unwrap().1;
        assert!(last < first * 0.5, "loss {first} -> {last}");
    }

    #[test]
    fn per_probe_dispatch_matches_batched() {
        // Same seed, same estimator: the two dispatch modes must walk the
        // same trajectory (same steps, same call accounting, and loss
        // curves equal to float tolerance).
        let mk = |dispatch: ProbeDispatch| TrainConfig {
            cosine_schedule: false,
            probe_dispatch: dispatch,
            ..TrainConfig::algorithm2("zo_sgd_plain", 0.05, 600)
        };
        let mut tb = Trainer::new(mk(ProbeDispatch::Batched), quad(16), mini_corpus()).unwrap();
        let mut tp = Trainer::new(mk(ProbeDispatch::PerProbe), quad(16), mini_corpus()).unwrap();
        let ob = tb.run(None).unwrap();
        let op = tp.run(None).unwrap();
        assert_eq!(ob.steps, op.steps);
        assert_eq!(ob.oracle_calls, op.oracle_calls);
        // identical call axis everywhere; identical losses on step 1 (before
        // f32 rounding differences can compound), co-descent at the end
        for ((cb, _), (cp, _)) in ob.loss_curve.iter().zip(op.loss_curve.iter()) {
            assert_eq!(cb, cp);
        }
        let (b0, p0) = (ob.loss_curve[0].1, op.loss_curve[0].1);
        assert!((b0 - p0).abs() <= 1e-6 * (1.0 + b0.abs()), "{b0} vs {p0}");
        let (bn, pn) = (
            ob.loss_curve.last().unwrap().1,
            op.loss_curve.last().unwrap().1,
        );
        assert!(bn < b0 * 0.9 && pn < p0 * 0.9, "both modes must descend");
    }

    #[test]
    fn probe_dispatch_parse_roundtrip() {
        assert_eq!(ProbeDispatch::parse("batched").unwrap(), ProbeDispatch::Batched);
        assert_eq!(ProbeDispatch::parse("per-probe").unwrap(), ProbeDispatch::PerProbe);
        assert!(ProbeDispatch::parse("warp").is_err());
        assert_eq!(ProbeDispatch::default(), ProbeDispatch::Batched);
    }

    #[test]
    fn outcome_label_describes_setup() {
        let cfg = TrainConfig::algorithm2("zo_adamm", 1e-3, 12);
        let mut t = Trainer::new(cfg, quad(4), mini_corpus()).unwrap();
        let out = t.run(None).unwrap();
        assert!(out.label.contains("bestofk5"));
        assert!(out.label.contains("ldsd"));
        assert!(out.label.contains("zo_adamm"));
    }

    #[test]
    fn streamed_storage_walks_identical_trajectory() {
        // The PR 3 acceptance property at the trainer level: materialized
        // and streamed probe storage produce bit-identical loss curves and
        // final parameters (see also tests/probe_storage.rs for the
        // randomized sweep).
        let d = 512;
        let run = |storage: ProbeStorage| {
            let cfg = TrainConfig {
                cosine_schedule: false,
                probe_storage: storage,
                ..TrainConfig::algorithm2("zo_sgd_plain", 0.05, 360)
            };
            let oracle = quad(d);
            let corpus = mini_corpus();
            let mut t = Trainer::with_exec(
                cfg,
                oracle,
                corpus,
                ExecContext::new(2).with_shard_len(100),
            )
            .unwrap();
            let out = t.run(None).unwrap();
            (out.loss_curve, t.oracle().params().to_vec())
        };
        let (curve_m, params_m) = run(ProbeStorage::Materialized);
        let (curve_s, params_s) = run(ProbeStorage::Streamed);
        assert_eq!(curve_m.len(), curve_s.len());
        for ((cm, lm), (cs, ls)) in curve_m.iter().zip(curve_s.iter()) {
            assert_eq!(cm, cs);
            assert_eq!(lm.to_bits(), ls.to_bits(), "{lm} vs {ls}");
        }
        for (a, b) in params_m.iter().zip(params_s.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn per_probe_dispatch_forces_materialized() {
        // explicit streamed + per-probe dispatch is contradictory: error
        let cfg = TrainConfig {
            probe_dispatch: ProbeDispatch::PerProbe,
            probe_storage: ProbeStorage::Streamed,
            ..TrainConfig::algorithm2("zo_sgd_plain", 0.05, 60)
        };
        if ProbeStorage::from_env().is_none() {
            let err = Trainer::new(cfg, quad(8), mini_corpus()).err().unwrap();
            assert!(err.to_string().contains("batched dispatch"), "{err}");
        }
        // auto + per-probe quietly stays materialized and runs
        let cfg2 = TrainConfig {
            probe_dispatch: ProbeDispatch::PerProbe,
            probe_storage: ProbeStorage::Auto,
            ..TrainConfig::algorithm2("zo_sgd_plain", 0.05, 60)
        };
        let mut t = Trainer::new(cfg2, quad(8), mini_corpus()).unwrap();
        assert!(t.run(None).is_ok());
    }

    #[test]
    fn snapshot_restore_resumes_bit_exactly_in_memory() {
        // one uninterrupted run vs snapshot-at-step-7 + restore onto a
        // fresh trainer: identical loss curve and final parameters
        let d = 64;
        let cfg = || TrainConfig {
            cosine_schedule: true,
            ..TrainConfig::algorithm2("zo_adamm", 0.01, 240)
        };
        let mut full = Trainer::new(cfg(), quad(d), mini_corpus()).unwrap();
        let full_out = full.run(None).unwrap();
        assert!(full_out.completed);

        let mut first = Trainer::new(
            TrainConfig {
                checkpoint: CheckpointConfig { max_run_steps: 7, ..Default::default() },
                ..cfg()
            },
            quad(d),
            mini_corpus(),
        )
        .unwrap();
        let partial = first.run(None).unwrap();
        assert!(!partial.completed);
        assert_eq!(partial.steps, 7);
        let snap = first.snapshot();

        let mut second = Trainer::new(cfg(), quad(d), mini_corpus()).unwrap();
        second.restore(&snap).unwrap();
        let resumed = second.run(None).unwrap();
        assert!(resumed.completed);
        assert_eq!(resumed.steps, full_out.steps);
        assert_eq!(resumed.oracle_calls, full_out.oracle_calls);
        assert_eq!(resumed.loss_curve.len(), full_out.loss_curve.len());
        for ((ca, la), (cb, lb)) in
            full_out.loss_curve.iter().zip(resumed.loss_curve.iter())
        {
            assert_eq!(ca, cb);
            assert_eq!(la.to_bits(), lb.to_bits(), "{la} vs {lb}");
        }
        for (a, b) in full.oracle().params().iter().zip(second.oracle().params()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn restore_rejects_mismatched_config() {
        let d = 8;
        let mut a =
            Trainer::new(TrainConfig::algorithm2("zo_sgd_plain", 0.05, 120), quad(d), mini_corpus())
                .unwrap();
        let snap = a.snapshot();
        // different optimizer -> different fingerprint label
        let mut b =
            Trainer::new(TrainConfig::algorithm2("zo_adamm", 0.05, 120), quad(d), mini_corpus())
                .unwrap();
        let err = b.restore(&snap).unwrap_err();
        assert!(err.to_string().contains("fingerprint"), "{err}");
        // different seed
        let mut c = Trainer::new(
            TrainConfig { seed: 9, ..TrainConfig::algorithm2("zo_sgd_plain", 0.05, 120) },
            quad(d),
            mini_corpus(),
        )
        .unwrap();
        assert!(c.restore(&snap).is_err());
        // same config restores fine
        let mut ok =
            Trainer::new(TrainConfig::algorithm2("zo_sgd_plain", 0.05, 120), quad(d), mini_corpus())
                .unwrap();
        ok.restore(&snap).unwrap();
    }

    #[test]
    fn checkpointed_run_resumes_from_disk_via_config() {
        // the config-driven path end to end: run with --checkpoint-every
        // until preemption, then build a fresh trainer with --resume and
        // finish; outcome must match the uninterrupted run bit for bit
        let d = 48;
        let dir = std::env::temp_dir().join(format!(
            "zo_train_ck_{}_{}",
            std::process::id(),
            line!()
        ));
        std::fs::remove_dir_all(&dir).ok();
        let base = || TrainConfig {
            cosine_schedule: false,
            ..TrainConfig::algorithm2("zo_sgd", 0.05, 300)
        };
        let mut full = Trainer::new(base(), quad(d), mini_corpus()).unwrap();
        let full_out = full.run(None).unwrap();

        let ck = |resume: bool, max_run_steps: u64| CheckpointConfig {
            dir: Some(dir.to_string_lossy().into_owned()),
            every: 3,
            resume,
            max_run_steps,
            store_dir: None,
        };
        let mut first = Trainer::new(
            TrainConfig { checkpoint: ck(false, 11), ..base() },
            quad(d),
            mini_corpus(),
        )
        .unwrap();
        let partial = first.run(None).unwrap();
        assert!(!partial.completed);
        let store = crate::store::Store::open(dir.join("store"));
        assert!(crate::snapshot::load_latest(&dir, Some(&store)).is_some());

        let mut second = Trainer::new(
            TrainConfig { checkpoint: ck(true, 0), ..base() },
            quad(d),
            mini_corpus(),
        )
        .unwrap();
        let resumed = second.run(None).unwrap();
        assert!(resumed.completed);
        assert_eq!(resumed.steps, full_out.steps);
        for ((ca, la), (cb, lb)) in
            full_out.loss_curve.iter().zip(resumed.loss_curve.iter())
        {
            assert_eq!(ca, cb);
            assert_eq!(la.to_bits(), lb.to_bits());
        }
        for (a, b) in full.oracle().params().iter().zip(second.oracle().params()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn shuffled_stream_resumes_bit_exactly_and_stamps_fingerprint() {
        // cursor mechanics: a snapshot mid-epoch carries the batch cursor,
        // a restored run continues bitwise (the data-dependent version of
        // this property lives in tests/mlp_train.rs — the quadratic
        // oracle ignores batches)
        let d = 32;
        let base = || TrainConfig {
            cosine_schedule: false,
            shuffle: Some(ShuffleSpec { n_train: 24 }),
            ..TrainConfig::algorithm2("zo_sgd_plain", 0.05, 240)
        };
        let mut full = Trainer::new(base(), quad(d), mini_corpus()).unwrap();
        let full_out = full.run(None).unwrap();

        let mut first = Trainer::new(
            TrainConfig {
                checkpoint: CheckpointConfig { max_run_steps: 4, ..Default::default() },
                ..base()
            },
            quad(d),
            mini_corpus(),
        )
        .unwrap();
        let partial = first.run(None).unwrap();
        assert!(!partial.completed);
        let snap = first.snapshot();
        assert_eq!(snap.data_cursor, 4 * 8, "cursor counts examples consumed");
        assert!(snap.fingerprint.label.contains("shuffle24"), "{:?}", snap.fingerprint);

        let mut second = Trainer::new(base(), quad(d), mini_corpus()).unwrap();
        second.restore(&snap).unwrap();
        assert_eq!(second.progress().data_cursor, 32);
        let resumed = second.run(None).unwrap();
        assert_eq!(resumed.steps, full_out.steps);
        for ((ca, la), (cb, lb)) in
            full_out.loss_curve.iter().zip(resumed.loss_curve.iter())
        {
            assert_eq!(ca, cb);
            assert_eq!(la.to_bits(), lb.to_bits());
        }
        for (a, b) in full.oracle().params().iter().zip(second.oracle().params()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }

        // a sequential run must refuse this shuffled snapshot
        let mut seq = Trainer::new(
            TrainConfig { shuffle: None, ..base() },
            quad(d),
            mini_corpus(),
        )
        .unwrap();
        assert!(seq.restore(&snap).is_err());
    }

    #[test]
    fn explicit_streamed_over_sphere_sampler_errors() {
        let cfg = TrainConfig {
            estimator: EstimatorKind::BestOfK { k: 3, sampler: SamplerKind::Sphere },
            probe_storage: ProbeStorage::Streamed,
            ..TrainConfig::algorithm2("zo_sgd_plain", 0.05, 60)
        };
        if ProbeStorage::from_env().is_none() {
            assert!(Trainer::new(cfg, quad(8), mini_corpus()).is_err());
        }
    }
}
