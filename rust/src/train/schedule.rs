//! Learning-rate schedules (§A.2: cosine schedule for gamma_x).

/// Maps an optimizer step index to a learning rate.
pub trait LrSchedule {
    /// Learning rate for step `step`.
    fn lr(&self, step: u64) -> f32;
}

/// A constant learning rate.
pub struct ConstantLr(
    /// The rate.
    pub f32,
);

impl LrSchedule for ConstantLr {
    fn lr(&self, _step: u64) -> f32 {
        self.0
    }
}

/// Cosine decay from `base` to ~0 over `total` steps (no restarts).
pub struct CosineLr {
    base: f32,
    total: u64,
}

impl CosineLr {
    /// Decay from `base` to ~0 over `total` steps.
    pub fn new(base: f32, total: u64) -> Self {
        Self { base, total: total.max(1) }
    }
}

impl LrSchedule for CosineLr {
    fn lr(&self, step: u64) -> f32 {
        let t = (step.min(self.total) as f64) / (self.total as f64);
        (self.base as f64 * 0.5 * (1.0 + (std::f64::consts::PI * t).cos())) as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cosine_starts_at_base_ends_near_zero() {
        let s = CosineLr::new(1.0, 100);
        assert!((s.lr(0) - 1.0).abs() < 1e-6);
        assert!((s.lr(50) - 0.5).abs() < 1e-6);
        assert!(s.lr(100) < 1e-6);
        // clamped past the horizon
        assert!(s.lr(1000) < 1e-6);
    }

    #[test]
    fn cosine_monotone_decreasing() {
        let s = CosineLr::new(0.1, 37);
        let mut prev = f32::INFINITY;
        for t in 0..=37 {
            let lr = s.lr(t);
            assert!(lr <= prev + 1e-9);
            prev = lr;
        }
    }

    #[test]
    fn constant_is_constant() {
        let s = ConstantLr(0.5);
        assert_eq!(s.lr(0), 0.5);
        assert_eq!(s.lr(999), 0.5);
    }
}
