//! Owned f32 vector with checked math — the parameter/state container.

use super::ops;

/// Owned f32 vector wrapper with convenience math.
#[derive(Clone, Debug, PartialEq)]
pub struct Vector(
    /// The underlying storage.
    pub Vec<f32>,
);

impl Vector {
    /// All-zero vector of length `n`.
    pub fn zeros(n: usize) -> Self {
        Self(vec![0.0; n])
    }

    /// Wrap an existing Vec.
    pub fn from_vec(v: Vec<f32>) -> Self {
        Self(v)
    }

    /// Element count.
    pub fn len(&self) -> usize {
        self.0.len()
    }

    /// True for a zero-length vector.
    pub fn is_empty(&self) -> bool {
        self.0.is_empty()
    }

    /// Borrow as a slice.
    pub fn as_slice(&self) -> &[f32] {
        &self.0
    }

    /// Borrow as a mutable slice.
    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.0
    }

    /// Inner product with `other`.
    pub fn dot(&self, other: &Vector) -> f32 {
        ops::dot(&self.0, &other.0)
    }

    /// Euclidean norm.
    pub fn norm(&self) -> f32 {
        ops::nrm2(&self.0)
    }

    /// In-place scaling by `a`.
    pub fn scale(&mut self, a: f32) {
        ops::scal(a, &mut self.0);
    }

    /// `self += a * other`.
    pub fn add_scaled(&mut self, a: f32, other: &Vector) {
        ops::axpy(a, &other.0, &mut self.0);
    }

    /// Normalize in place; returns the previous norm.
    pub fn normalize(&mut self) -> f32 {
        ops::normalize(&mut self.0)
    }

    /// Cosine similarity with `other`.
    pub fn cosine(&self, other: &Vector) -> f32 {
        ops::cosine(&self.0, &other.0)
    }
}

impl std::ops::Index<usize> for Vector {
    type Output = f32;
    fn index(&self, i: usize) -> &f32 {
        &self.0[i]
    }
}

impl std::ops::IndexMut<usize> for Vector {
    fn index_mut(&mut self, i: usize) -> &mut f32 {
        &mut self.0[i]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vector_ops_roundtrip() {
        let mut v = Vector::from_vec(vec![3.0, 4.0]);
        assert_eq!(v.norm(), 5.0);
        v.normalize();
        assert!((v.norm() - 1.0).abs() < 1e-6);
        let w = Vector::from_vec(vec![1.0, 0.0]);
        assert!((v.cosine(&w) - 0.6).abs() < 1e-6);
    }

    #[test]
    fn add_scaled() {
        let mut v = Vector::zeros(3);
        let w = Vector::from_vec(vec![1.0, 2.0, 3.0]);
        v.add_scaled(2.0, &w);
        assert_eq!(v.as_slice(), &[2.0, 4.0, 6.0]);
    }
}
