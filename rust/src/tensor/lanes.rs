//! Explicitly vectorized wide-lane kernels with a bitwise lane contract
//! (DESIGN.md §14).
//!
//! Every kernel here exists in two compiled forms sharing ONE body:
//! * **scalar** — the body compiled with the crate's baseline target
//!   features; fused multiplies go through [`f32::mul_add`], which lowers
//!   to the correctly-rounded `fmaf` libcall.
//! * **wide** — the *same body* compiled inside an
//!   `#[target_feature(enable = "avx2", enable = "fma")]` clone, where
//!   LLVM vectorizes the `mul_add` loops into 8-lane `vfmadd` and the
//!   plain mul/add loops into 8-lane `vmul`/`vadd`.
//!
//! The bitwise contract rests on two facts: IEEE-754 `fusedMultiplyAdd`
//! is correctly rounded, so the libcall and the hardware instruction
//! return identical bits for every input; and rustc never enables
//! floating-point contraction, so plain `a * b + c` expressions are never
//! silently fused under `target_feature`.  Kernels whose arithmetic is
//! elementwise (axpy family) are trivially chunking-invariant; the one
//! reducing kernel ([`dot_lanes`]) accumulates into [`LANES`] fixed f64
//! partials in a pinned element-to-lane assignment and reduces them in
//! pinned index order, mirroring the shard contract — so results are
//! bit-identical at any lane width, thread count, and probe-storage mode.
//!
//! Mode selection: `ZO_LANES=scalar|wide` (invalid values panic loudly),
//! defaulting to wide when the CPU supports avx2+fma.  Forcing `wide` on
//! a CPU without those features falls back to the scalar body — which is
//! bit-identical by the contract, so the request is honored semantically.
//! [`force_mode`] overrides both for A/B benches and property tests; the
//! race it could theoretically lose is harmless because both modes return
//! identical bits.

use std::sync::atomic::{AtomicU8, Ordering};

/// Lane width of the wide kernels (8 f32 lanes = one AVX2 register).
pub const LANES: usize = 8;

/// Which kernel family executes: the scalar bodies or their
/// `target_feature` wide clones.  Both return identical bits; the mode
/// only changes speed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LaneMode {
    /// Baseline-feature bodies (`mul_add` = `fmaf` libcall).
    Scalar,
    /// avx2+fma clones (vectorized `vfmadd`), when the CPU has them.
    Wide,
}

impl LaneMode {
    /// Parse `"scalar"` / `"wide"`.
    pub fn parse(s: &str) -> Option<LaneMode> {
        match s {
            "scalar" => Some(LaneMode::Scalar),
            "wide" => Some(LaneMode::Wide),
            _ => None,
        }
    }

    /// The label used in env vars and bench row names.
    pub fn label(&self) -> &'static str {
        match self {
            LaneMode::Scalar => "scalar",
            LaneMode::Wide => "wide",
        }
    }
}

// 0 = uninitialized, 1 = scalar, 2 = wide (idempotent lazy init — a race
// recomputes the same value).
static ENV_MODE: AtomicU8 = AtomicU8::new(0);
// 0 = uninitialized, 1 = no, 2 = yes
static CPU_WIDE: AtomicU8 = AtomicU8::new(0);
// 0 = no override, 1 = forced scalar, 2 = forced wide
static FORCED: AtomicU8 = AtomicU8::new(0);

fn cpu_wide() -> bool {
    match CPU_WIDE.load(Ordering::Relaxed) {
        1 => false,
        2 => true,
        _ => {
            #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
            let has = is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma");
            #[cfg(not(any(target_arch = "x86", target_arch = "x86_64")))]
            let has = false;
            CPU_WIDE.store(if has { 2 } else { 1 }, Ordering::Relaxed);
            has
        }
    }
}

/// The configured lane mode: `ZO_LANES` if set (panicking on anything but
/// `scalar`/`wide` — a typo must not silently change the benchmark), else
/// [`LaneMode::Wide`] when the CPU supports it.
pub fn lane_mode() -> LaneMode {
    match ENV_MODE.load(Ordering::Relaxed) {
        1 => LaneMode::Scalar,
        2 => LaneMode::Wide,
        _ => {
            let mode = match std::env::var("ZO_LANES") {
                Ok(v) => LaneMode::parse(&v).unwrap_or_else(|| {
                    panic!("ZO_LANES must be 'scalar' or 'wide', got '{v}'")
                }),
                Err(_) => {
                    if cpu_wide() {
                        LaneMode::Wide
                    } else {
                        LaneMode::Scalar
                    }
                }
            };
            ENV_MODE.store(
                match mode {
                    LaneMode::Scalar => 1,
                    LaneMode::Wide => 2,
                },
                Ordering::Relaxed,
            );
            mode
        }
    }
}

/// Process-wide mode override for A/B benches and scalar-vs-wide property
/// tests; `None` restores the `ZO_LANES`/detection default.  Safe to flip
/// at any time — the two modes are bit-identical, so a concurrently
/// running kernel can only change speed, never results.
pub fn force_mode(mode: Option<LaneMode>) {
    FORCED.store(
        match mode {
            None => 0,
            Some(LaneMode::Scalar) => 1,
            Some(LaneMode::Wide) => 2,
        },
        Ordering::Relaxed,
    );
}

/// The mode kernels dispatch on right now ([`force_mode`] override, else
/// [`lane_mode`]).
pub fn effective_mode() -> LaneMode {
    match FORCED.load(Ordering::Relaxed) {
        1 => LaneMode::Scalar,
        2 => LaneMode::Wide,
        _ => lane_mode(),
    }
}

/// True when dispatchers should take the avx2+fma clone ([`lane_kernel!`]
/// reads it; `pub(crate)` so sibling modules — [`super::gemm`] — can stamp
/// their own kernels from the same macro).
#[inline]
pub(crate) fn wide_active() -> bool {
    effective_mode() == LaneMode::Wide && cpu_wide()
}

/// Generate the public dispatcher + the avx2/fma wide clone for one
/// kernel body.  The clone's body IS the scalar body (inlined into the
/// `target_feature` context), so the two forms cannot drift.
macro_rules! lane_kernel {
    ($(#[$doc:meta])* $name:ident / $wide:ident => $body:ident
     ($($arg:ident: $ty:ty),*) $(-> $ret:ty)?) => {
        $(#[$doc])*
        #[inline]
        pub fn $name($($arg: $ty),*) $(-> $ret)? {
            #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
            {
                if $crate::tensor::lanes::wide_active() {
                    // SAFETY: wide_active() is true only after runtime
                    // detection of avx2+fma on this CPU.
                    unsafe {
                        return $wide($($arg),*);
                    }
                }
            }
            $body($($arg),*)
        }

        #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
        #[target_feature(enable = "avx2", enable = "fma")]
        unsafe fn $wide($($arg: $ty),*) $(-> $ret)? {
            $body($($arg),*)
        }
    };
}

// the blocked GEMM engine stamps its microkernel from the same macro, so
// its scalar/wide forms share one body exactly like the kernels here
pub(crate) use lane_kernel;

#[inline(always)]
fn fma_axpy_body(a: f32, x: &[f32], y: &mut [f32]) {
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi = a.mul_add(*xi, *yi);
    }
}

#[inline(always)]
fn fma_axpy_into_body(out: &mut [f32], x: &[f32], a: f32, d: &[f32]) {
    for i in 0..out.len() {
        out[i] = a.mul_add(d[i], x[i]);
    }
}

#[inline(always)]
fn fma_perturb_fill_body(x: &[f32], tau: f32, v: &[f32], z: &mut [f32]) {
    for i in 0..z.len() {
        z[i] = tau.mul_add(v[i], x[i]);
    }
}

// pub(crate): the blocked GEMM microkernel inlines this exact body into
// its own tile loop, so the packed kernel's per-element arithmetic IS the
// golden-pinned unfused accum_row update
#[inline(always)]
pub(crate) fn accum_row_body(xi: f32, w: &[f32], out: &mut [f32]) {
    for (o, wv) in out.iter_mut().zip(w.iter()) {
        *o += xi * *wv;
    }
}

#[inline(always)]
fn dot_lanes_body(x: &[f32], y: &[f32]) -> f64 {
    let n = x.len();
    let mut acc = [0.0f64; LANES];
    let chunks = n / LANES;
    for c in 0..chunks {
        let base = c * LANES;
        for j in 0..LANES {
            acc[j] += x[base + j] as f64 * y[base + j] as f64;
        }
    }
    let tail = chunks * LANES;
    for j in 0..n - tail {
        acc[j] += x[tail + j] as f64 * y[tail + j] as f64;
    }
    // pinned index-order reduce of the lane partials
    let mut s = 0.0f64;
    for a in acc.iter() {
        s += *a;
    }
    s
}

lane_kernel! {
    /// y += a * x, fused: `y[i] = a.mul_add(x[i], y[i])`.  The shared
    /// accumulation primitive behind `axpy`, the `axpy_k` row loop and
    /// `replay_axpy` — all three run this exact body, which is what makes
    /// the fused/looped/replayed paths bit-identical.
    fma_axpy / fma_axpy_wide => fma_axpy_body(a: f32, x: &[f32], y: &mut [f32])
}

lane_kernel! {
    /// out = x + a * d, fused: `out[i] = a.mul_add(d[i], x[i])`.  The
    /// perturbed-iterate primitive behind `axpy_into` and every oracle's
    /// `w = x + tau * v` materialization (slice and streamed alike).
    fma_axpy_into / fma_axpy_into_wide =>
        fma_axpy_into_body(out: &mut [f32], x: &[f32], a: f32, d: &[f32])
}

lane_kernel! {
    /// z = x + tau * v into a caller chunk buffer, fused — the vectorized
    /// core of `perturb_eval` (the streamed closed-form path computes z in
    /// chunks here, then feeds elements to the visitor in index order).
    fma_perturb_fill / fma_perturb_fill_wide =>
        fma_perturb_fill_body(x: &[f32], tau: f32, v: &[f32], z: &mut [f32])
}

lane_kernel! {
    /// out += xi * w, UNfused (separate mul and add) — the transformer
    /// matmul / LoRA inner row update.  Kept free of `mul_add` on purpose:
    /// the committed bitwise forward golden pins the unfused arithmetic,
    /// and rustc never contracts it, so the wide clone only widens the
    /// elementwise loop without changing any rounding.
    accum_row / accum_row_wide => accum_row_body(xi: f32, w: &[f32], out: &mut [f32])
}

lane_kernel! {
    /// Lane-partitioned f32 dot product with f64 accumulation: element i
    /// feeds lane partial `i % LANES`, partials reduce in pinned index
    /// order.  NOT bit-compatible with the sequential [`super::dot`] —
    /// use it only where no contract pins the sequential order (the MLP
    /// forward's per-unit reduction).  Both lane modes run this same
    /// body, so the result is bit-identical across modes by construction.
    dot_lanes / dot_lanes_wide => dot_lanes_body(x: &[f32], y: &[f32]) -> f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn fill(rng: &mut Rng, n: usize) -> Vec<f32> {
        let mut v = vec![0.0f32; n];
        rng.fill_normal(&mut v);
        v
    }

    #[test]
    fn parse_and_label_roundtrip() {
        assert_eq!(LaneMode::parse("scalar"), Some(LaneMode::Scalar));
        assert_eq!(LaneMode::parse("wide"), Some(LaneMode::Wide));
        assert_eq!(LaneMode::parse("turbo"), None);
        assert_eq!(LaneMode::Scalar.label(), "scalar");
        assert_eq!(LaneMode::Wide.label(), "wide");
    }

    #[test]
    fn scalar_vs_wide_bitwise_identical() {
        // the lane contract itself: every kernel returns identical bits in
        // both modes (vacuously true on CPUs without avx2+fma, where wide
        // falls back to the scalar body)
        let mut rng = Rng::new(42);
        for n in [1usize, 7, 8, 9, 64, 1000, 4099] {
            let x = fill(&mut rng, n);
            let d = fill(&mut rng, n);
            let y0 = fill(&mut rng, n);
            let a = 0.37f32;

            let run = |mode: LaneMode| {
                force_mode(Some(mode));
                let mut y = y0.clone();
                fma_axpy(a, &x, &mut y);
                let mut o = vec![0.0f32; n];
                fma_axpy_into(&mut o, &x, a, &d);
                let mut z = vec![0.0f32; n];
                fma_perturb_fill(&x, a, &d, &mut z);
                let mut r = y0.clone();
                accum_row(a, &x, &mut r);
                let dp = dot_lanes(&x, &d);
                force_mode(None);
                (y, o, z, r, dp)
            };
            let (ys, os, zs, rs, ds) = run(LaneMode::Scalar);
            let (yw, ow, zw, rw, dw) = run(LaneMode::Wide);
            for (a, b) in ys.iter().zip(yw.iter()) {
                assert_eq!(a.to_bits(), b.to_bits(), "fma_axpy n={n}");
            }
            for (a, b) in os.iter().zip(ow.iter()) {
                assert_eq!(a.to_bits(), b.to_bits(), "fma_axpy_into n={n}");
            }
            for (a, b) in zs.iter().zip(zw.iter()) {
                assert_eq!(a.to_bits(), b.to_bits(), "fma_perturb_fill n={n}");
            }
            for (a, b) in rs.iter().zip(rw.iter()) {
                assert_eq!(a.to_bits(), b.to_bits(), "accum_row n={n}");
            }
            assert_eq!(ds.to_bits(), dw.to_bits(), "dot_lanes n={n}");
        }
    }

    #[test]
    fn fma_axpy_is_fused() {
        // pick values where fused and unfused rounding differ: with
        // a = 1 + 2^-12, x = 1 + 2^-12, y = -1, the product 1 + 2^-11 +
        // 2^-24 is not representable in f32, so the unfused path rounds
        // it before adding while fma keeps the 2^-24 term
        let a = 1.0f32 + 2.0f32.powi(-12);
        let x = [a];
        let mut y = [-1.0f32];
        fma_axpy(a, &x, &mut y);
        let fused = a.mul_add(a, -1.0f32);
        let unfused = a * a - 1.0f32;
        assert_eq!(y[0].to_bits(), fused.to_bits());
        assert_ne!(
            fused.to_bits(),
            unfused.to_bits(),
            "test values must distinguish fused from unfused rounding"
        );
    }

    #[test]
    fn dot_lanes_matches_lane_partial_reference() {
        let mut rng = Rng::new(7);
        let n = 1003;
        let x = fill(&mut rng, n);
        let y = fill(&mut rng, n);
        // independent reference: the documented lane-partial recurrence
        let mut acc = [0.0f64; LANES];
        for i in 0..n {
            acc[i % LANES] += x[i] as f64 * y[i] as f64;
        }
        let mut want = 0.0f64;
        for a in acc.iter() {
            want += *a;
        }
        assert_eq!(dot_lanes(&x, &y).to_bits(), want.to_bits());
    }

    #[test]
    fn accum_row_stays_unfused() {
        // the golden-pinned transformer arithmetic: out[j] + xi*w[j] with
        // an intermediate rounding of the product
        let xi = 1.0f32 + 2.0f32.powi(-12);
        let w = [xi];
        let mut out = [-1.0f32];
        accum_row(xi, &w, &mut out);
        assert_eq!(out[0].to_bits(), (xi * xi - 1.0f32).to_bits());
    }
}
