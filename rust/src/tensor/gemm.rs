//! Cache-blocked batched GEMM with a bitwise tiling contract
//! (DESIGN.md §15).
//!
//! The ZO hot loop spends its budget on model forwards, and after the
//! probe kernels vectorized (§14) the binding cost is the matmul inside
//! every forward: `model::transformer`'s reference loop walks one input
//! row at a time and re-streams the whole weight matrix per row (and per
//! probe).  This module batches those products — `C[m,n] = bias +
//! A[m,k] · B[k,n]` over all `m = batch·seq` rows at once — through a
//! register-tiled, panel-packed kernel, under a contract strong enough to
//! keep every committed golden valid:
//!
//! **The tiling contract.**  Tiles may partition the m (rows) and n
//! (output columns) dimensions freely, but the k-reduction of every
//! output element must run sequentially in ascending index order, seeded
//! from the bias, with the exact unfused `c += a * b` update of
//! [`crate::tensor::lanes::accum_row`].  Each output element is then
//! produced by the identical f32 addition sequence as the reference
//! row-at-a-time loop — m/n tiling only changes *which order the
//! independent elements are produced in*, and copies between the packed
//! C-tile and the output are bit-free.  Splitting k (split-k trees,
//! k-panel accumulators) would reorder the additions and is forbidden.
//! Consequence: [`gemm_blocked`] is bitwise identical to
//! [`gemm_reference`] at any tile size, lane mode and thread count, so
//! the transformer parity/f32 goldens and every train-trajectory golden
//! hold unchanged under either engine (`tests/gemm_contract.rs` pins
//! this property over randomized shapes).
//!
//! **Packing.**  [`PackedB`] stores B as NR-wide column panels
//! (`panel[kk * nr + jj]`), so the microkernel reads one contiguous
//! B-row slice per k-step and reuses it across the whole MR-row tile —
//! ~MR× fewer B loads than the reference loop, which is where the
//! speedup comes from.  Packing is a pure copy (bit-free) and amortizes:
//! frozen LoRA base weights pack **once per run**, FT-mode weights
//! repack once per probe window (cost O(d), the same order as forming
//! the perturbation itself).
//!
//! **Mode selection** mirrors `ZO_LANES`: `ZO_GEMM=reference|blocked`
//! (invalid values panic loudly), defaulting to blocked.  The trainer
//! threads `TrainConfig::gemm` through [`set_run_mode`] under the
//! uniform precedence contract (an explicit off-default config beats
//! the env override, like `ZO_PARAM_STORE`; DESIGN.md §17e), and
//! [`force_gemm_mode`] pins the mode for A/B benches and property tests.
//! Both engines return identical bits, so a stale or racing mode switch
//! can only change speed, never results.

use std::sync::atomic::{AtomicU8, Ordering};

use super::lanes::{accum_row, accum_row_body, dot_lanes, lane_kernel};

/// Row-tile height of the blocked microkernel (output rows per C-tile).
pub const MR: usize = 8;

/// Column-panel width of [`PackedB`] (output columns per C-tile; 64 f32
/// = two cache lines per packed B-row).
pub const NR: usize = 64;

/// Which GEMM engine the model forwards run: the reference
/// row-at-a-time loop or the blocked panel-packed kernel.  Both return
/// identical bits; the mode only changes speed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GemmMode {
    /// The row-at-a-time `matmul` loop the goldens were blessed on.
    Reference,
    /// The cache-blocked, panel-packed batched kernel (default).
    Blocked,
}

impl GemmMode {
    /// Parse `"reference"` / `"blocked"`.
    pub fn parse(s: &str) -> Option<GemmMode> {
        match s {
            "reference" => Some(GemmMode::Reference),
            "blocked" => Some(GemmMode::Blocked),
            _ => None,
        }
    }

    /// The label used in env vars, CLI flags and bench row names.
    pub fn label(&self) -> &'static str {
        match self {
            GemmMode::Reference => "reference",
            GemmMode::Blocked => "blocked",
        }
    }
}

// 0 = uninitialized, 1 = reference, 2 = blocked (idempotent lazy init)
static ENV_MODE: AtomicU8 = AtomicU8::new(0);
// 0 = none, 1 = reference, 2 = blocked — the trainer-resolved run mode
static CONFIGURED: AtomicU8 = AtomicU8::new(0);
// 0 = no override, 1 = forced reference, 2 = forced blocked
static FORCED: AtomicU8 = AtomicU8::new(0);

fn enc(mode: GemmMode) -> u8 {
    match mode {
        GemmMode::Reference => 1,
        GemmMode::Blocked => 2,
    }
}

/// The configured GEMM engine: `ZO_GEMM` if set (panicking on anything
/// but `reference`/`blocked` — a typo must not silently change the
/// benchmark), else [`GemmMode::Blocked`].
pub fn gemm_mode() -> GemmMode {
    match ENV_MODE.load(Ordering::Relaxed) {
        1 => GemmMode::Reference,
        2 => GemmMode::Blocked,
        _ => {
            let mode = match std::env::var("ZO_GEMM") {
                Ok(v) => GemmMode::parse(&v).unwrap_or_else(|| {
                    panic!("ZO_GEMM must be 'reference' or 'blocked', got '{v}'")
                }),
                Err(_) => GemmMode::Blocked,
            };
            ENV_MODE.store(enc(mode), Ordering::Relaxed);
            mode
        }
    }
}

/// Install the trainer-resolved run mode (config + `ZO_GEMM`), below the
/// [`force_gemm_mode`] override.  Process-wide like the lane mode: two
/// concurrent trainers with different configs race harmlessly, because
/// both engines are bit-identical.
pub fn set_run_mode(mode: Option<GemmMode>) {
    CONFIGURED.store(mode.map(enc).unwrap_or(0), Ordering::Relaxed);
}

/// Process-wide override for A/B benches and blocked-vs-reference
/// property tests; `None` restores the configured/`ZO_GEMM` default.
pub fn force_gemm_mode(mode: Option<GemmMode>) {
    FORCED.store(mode.map(enc).unwrap_or(0), Ordering::Relaxed);
}

/// The engine the model forwards dispatch on right now
/// ([`force_gemm_mode`] override, else the trainer-installed run mode,
/// else [`gemm_mode`]).
pub fn effective_gemm_mode() -> GemmMode {
    match FORCED.load(Ordering::Relaxed) {
        1 => GemmMode::Reference,
        2 => GemmMode::Blocked,
        _ => match CONFIGURED.load(Ordering::Relaxed) {
            1 => GemmMode::Reference,
            2 => GemmMode::Blocked,
            _ => gemm_mode(),
        },
    }
}

/// B `[k, n]` repacked into NR-wide column panels: panel `p` holds
/// columns `p*nr .. min((p+1)*nr, n)` row-major-within-panel
/// (`panel[kk * width + jj]`), panels concatenated tightly.  The
/// microkernel reads one contiguous `width`-long B-row slice per k-step
/// and reuses it across the whole row tile.  Packing is a pure copy —
/// no arithmetic — so it cannot perturb the tiling contract.
#[derive(Clone, Debug)]
pub struct PackedB {
    k: usize,
    n: usize,
    nr: usize,
    data: Vec<f32>,
}

impl PackedB {
    /// Pack row-major `b` (`k x n`) with the default panel width
    /// [`NR`].
    pub fn pack(b: &[f32], k: usize, n: usize) -> Self {
        Self::pack_with_nr(b, k, n, NR)
    }

    /// [`PackedB::pack`] with an explicit panel width (property tests
    /// sweep this; the contract holds at any width).
    pub fn pack_with_nr(b: &[f32], k: usize, n: usize, nr: usize) -> Self {
        assert!(nr > 0, "panel width must be positive");
        let mut p = Self { k: 0, n: 0, nr, data: Vec::new() };
        p.repack(b, k, n);
        p
    }

    /// An empty pack that [`PackedB::repack`] fills later (worker-local
    /// scratch: allocate once, repack per probe window with no further
    /// heap traffic).
    pub fn empty() -> Self {
        Self { k: 0, n: 0, nr: NR, data: Vec::new() }
    }

    /// Re-pack `b` (`k x n`) in place, reusing the existing allocation
    /// when the shape fits — the FT-mode per-probe repack path.
    pub fn repack(&mut self, b: &[f32], k: usize, n: usize) {
        assert_eq!(b.len(), k * n, "b must be k x n");
        self.k = k;
        self.n = n;
        self.data.clear();
        self.data.resize(k * n, 0.0);
        let nr = self.nr;
        let mut at = 0usize;
        let mut j0 = 0usize;
        while j0 < n {
            let w = nr.min(n - j0);
            for kk in 0..k {
                let src = &b[kk * n + j0..kk * n + j0 + w];
                self.data[at + kk * w..at + (kk + 1) * w].copy_from_slice(src);
            }
            at += k * w;
            j0 += w;
        }
    }

    /// Reduction length k.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Output width n.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Panel width this pack was built with.
    pub fn nr(&self) -> usize {
        self.nr
    }

    /// Resident f32 count (pack-cache memory accounting).
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True when nothing has been packed yet.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

#[inline(always)]
fn gemm_tile_body(at: &[f32], k: usize, rows: usize, panel: &[f32], w: usize, ctile: &mut [f32]) {
    // ascending-k accumulation into the packed C-tile: per output
    // element this is bias-init (done by the caller) followed by the
    // exact unfused accum_row update sequence of the reference loop
    for kk in 0..k {
        let brow = &panel[kk * w..(kk + 1) * w];
        for r in 0..rows {
            accum_row_body(at[r * k + kk], brow, &mut ctile[r * w..(r + 1) * w]);
        }
    }
}

lane_kernel! {
    /// One MR x NR microkernel call: `ctile += A_tile · B_panel` with
    /// the k-reduction ascending — the blocked engine's only arithmetic.
    /// Stamped from [`lane_kernel!`], so its scalar and avx2+fma wide
    /// forms share this one body and stay bit-identical by the §14 lane
    /// contract.
    gemm_tile / gemm_tile_wide =>
        gemm_tile_body(at: &[f32], k: usize, rows: usize, panel: &[f32], w: usize, ctile: &mut [f32])
}

/// The reference engine: `out = bias + a · b` row at a time, exactly the
/// loop `model::transformer::matmul` always ran (bias copy, then
/// ascending-k [`accum_row`] updates).  The committed f32 goldens pin
/// this arithmetic; [`gemm_blocked`] must (and does) reproduce it bit
/// for bit.
pub fn gemm_reference(
    a: &[f32],
    m: usize,
    k: usize,
    b: &[f32],
    n: usize,
    bias: Option<&[f32]>,
    out: &mut [f32],
) {
    debug_assert_eq!(a.len(), m * k, "a must be m x k");
    debug_assert_eq!(b.len(), k * n, "b must be k x n");
    debug_assert_eq!(out.len(), m * n, "out must be m x n");
    for i in 0..m {
        let orow = &mut out[i * n..(i + 1) * n];
        match bias {
            Some(bv) => orow.copy_from_slice(bv),
            None => orow.iter_mut().for_each(|v| *v = 0.0),
        }
        for (kk, &xi) in a[i * k..(i + 1) * k].iter().enumerate() {
            accum_row(xi, &b[kk * n..(kk + 1) * n], orow);
        }
    }
}

/// Blocked driver core over an explicit row-tile height and C-tile
/// scratch (`ctile` must hold at least `mr * pb.nr()` f32).  Panels are
/// the outer loop so one packed panel stays hot across every row tile;
/// per tile the C-block seeds from the bias, accumulates ascending-k via
/// [`gemm_tile`], and copies out — all bit-free moves around the
/// reference addition sequence.
pub fn gemm_blocked_with(
    a: &[f32],
    m: usize,
    k: usize,
    pb: &PackedB,
    bias: Option<&[f32]>,
    out: &mut [f32],
    mr: usize,
    ctile: &mut [f32],
) {
    let n = pb.n;
    assert!(mr > 0, "row tile must be positive");
    assert_eq!(pb.k, k, "pack reduction length mismatch");
    debug_assert_eq!(a.len(), m * k, "a must be m x k");
    debug_assert_eq!(out.len(), m * n, "out must be m x n");
    assert!(ctile.len() >= mr * pb.nr.min(n.max(1)), "ctile scratch too small");
    let mut at_panel = 0usize;
    let mut j0 = 0usize;
    while j0 < n {
        let w = pb.nr.min(n - j0);
        let panel = &pb.data[at_panel..at_panel + k * w];
        let mut i0 = 0usize;
        while i0 < m {
            let rows = mr.min(m - i0);
            // seed the packed C-tile from the bias (a copy, bit-free)
            for r in 0..rows {
                let crow = &mut ctile[r * w..(r + 1) * w];
                match bias {
                    Some(bv) => crow.copy_from_slice(&bv[j0..j0 + w]),
                    None => crow.iter_mut().for_each(|v| *v = 0.0),
                }
            }
            gemm_tile(&a[i0 * k..(i0 + rows) * k], k, rows, panel, w, &mut ctile[..rows * w]);
            // copy the finished tile back (bit-free)
            for r in 0..rows {
                out[(i0 + r) * n + j0..(i0 + r) * n + j0 + w]
                    .copy_from_slice(&ctile[r * w..(r + 1) * w]);
            }
            i0 += rows;
        }
        at_panel += k * w;
        j0 += w;
    }
}

/// The blocked engine at the default [`MR`] x [`NR`] tile with stack
/// C-tile scratch: `out = bias + a · B` where B was packed by
/// [`PackedB::pack`] (panel width must be <= [`NR`]).  Bitwise identical
/// to [`gemm_reference`] on the unpacked B by the tiling contract.
pub fn gemm_blocked(
    a: &[f32],
    m: usize,
    k: usize,
    pb: &PackedB,
    bias: Option<&[f32]>,
    out: &mut [f32],
) {
    assert!(pb.nr <= NR, "default-tile entry needs panel width <= NR");
    let mut ctile = [0.0f32; MR * NR];
    gemm_blocked_with(a, m, k, pb, bias, out, MR, &mut ctile);
}

/// Blocked GEMM over a *narrow unpacked* B (`n <= NR`): a single packed
/// panel of width n is laid out exactly like row-major B itself, so the
/// raw weight slice is already in packed form and the microkernel can
/// run on it directly — zero packing cost.  This is the path for LoRA
/// `x·A` products (n = r) and classifier heads (n = n_classes).
pub fn gemm_blocked_narrow(
    a: &[f32],
    m: usize,
    k: usize,
    b: &[f32],
    n: usize,
    bias: Option<&[f32]>,
    out: &mut [f32],
) {
    assert!(n <= NR, "narrow entry needs n <= NR (pack wider matrices)");
    debug_assert_eq!(a.len(), m * k, "a must be m x k");
    debug_assert_eq!(b.len(), k * n, "b must be k x n");
    debug_assert_eq!(out.len(), m * n, "out must be m x n");
    if n == 0 {
        return;
    }
    let mut ctile = [0.0f32; MR * NR];
    let mut i0 = 0usize;
    while i0 < m {
        let rows = MR.min(m - i0);
        for r in 0..rows {
            let crow = &mut ctile[r * n..(r + 1) * n];
            match bias {
                Some(bv) => crow.copy_from_slice(bv),
                None => crow.iter_mut().for_each(|v| *v = 0.0),
            }
        }
        gemm_tile(&a[i0 * k..(i0 + rows) * k], k, rows, b, n, &mut ctile[..rows * n]);
        for r in 0..rows {
            out[(i0 + r) * n..(i0 + r) * n + n].copy_from_slice(&ctile[r * n..(r + 1) * n]);
        }
        i0 += rows;
    }
}

/// Row-tile height of the lane-dot batched kernel (examples per block
/// that share one resident weight row).
pub const MB_LANES: usize = 32;

/// Batched MLP-style product with **row-major `[n, k]` weights** and the
/// §14 [`dot_lanes`] reduction: `out[i*n + j] = bias[j] +
/// dot_lanes(w_row_j, a_row_i) as f32` — the exact per-unit expression
/// of `model::mlp::forward_example`, evaluated for a whole minibatch.
/// The blocked engine hoists the unit loop outside a [`MB_LANES`]-row
/// block so each weight row is read once per block instead of once per
/// example; every output element is an independent closed-form
/// expression, so any loop order returns identical bits (this kernel
/// has no ordering freedom to constrain — the tiling contract is
/// trivially satisfied).
pub fn gemm_rowmajor_lanes(
    a: &[f32],
    m: usize,
    k: usize,
    w: &[f32],
    bias: &[f32],
    n: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(a.len(), m * k, "a must be m x k");
    debug_assert_eq!(w.len(), n * k, "w must be n x k (row-major units)");
    debug_assert_eq!(bias.len(), n, "one bias per unit");
    debug_assert_eq!(out.len(), m * n, "out must be m x n");
    match effective_gemm_mode() {
        GemmMode::Reference => {
            for i in 0..m {
                let xr = &a[i * k..(i + 1) * k];
                for j in 0..n {
                    out[i * n + j] = bias[j] + dot_lanes(&w[j * k..(j + 1) * k], xr) as f32;
                }
            }
        }
        GemmMode::Blocked => {
            let mut i0 = 0usize;
            while i0 < m {
                let rows = MB_LANES.min(m - i0);
                for j in 0..n {
                    let wr = &w[j * k..(j + 1) * k];
                    for r in 0..rows {
                        let i = i0 + r;
                        out[i * n + j] = bias[j] + dot_lanes(wr, &a[i * k..(i + 1) * k]) as f32;
                    }
                }
                i0 += rows;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn fill(rng: &mut Rng, n: usize) -> Vec<f32> {
        let mut v = vec![0.0f32; n];
        rng.fill_normal(&mut v);
        v
    }

    // the mode statics are process-wide and the test harness runs tests
    // concurrently; serialize every test that flips them so the
    // mode-introspection asserts can't observe a neighbor's override
    static MODE_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

    #[test]
    fn parse_and_label_roundtrip() {
        assert_eq!(GemmMode::parse("reference"), Some(GemmMode::Reference));
        assert_eq!(GemmMode::parse("blocked"), Some(GemmMode::Blocked));
        assert_eq!(GemmMode::parse("turbo"), None);
        assert_eq!(GemmMode::Reference.label(), "reference");
        assert_eq!(GemmMode::Blocked.label(), "blocked");
    }

    #[test]
    fn force_overrides_and_restores() {
        let _g = MODE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        force_gemm_mode(Some(GemmMode::Reference));
        assert_eq!(effective_gemm_mode(), GemmMode::Reference);
        force_gemm_mode(Some(GemmMode::Blocked));
        assert_eq!(effective_gemm_mode(), GemmMode::Blocked);
        force_gemm_mode(None);
        // run-mode tier sits under the force override
        set_run_mode(Some(GemmMode::Reference));
        assert_eq!(effective_gemm_mode(), GemmMode::Reference);
        set_run_mode(None);
    }

    #[test]
    fn pack_roundtrips_every_element() {
        let mut rng = Rng::new(3);
        for (k, n, nr) in [(5usize, 7usize, 3usize), (8, 64, 64), (4, 1, 8), (1, 9, 4)] {
            let b = fill(&mut rng, k * n);
            let pb = PackedB::pack_with_nr(&b, k, n, nr);
            assert_eq!(pb.len(), k * n, "packing is a permutation");
            // walk the documented layout back to row-major
            let mut seen = vec![0.0f32; k * n];
            let mut at = 0usize;
            let mut j0 = 0usize;
            while j0 < n {
                let w = nr.min(n - j0);
                for kk in 0..k {
                    for jj in 0..w {
                        seen[kk * n + j0 + jj] = pb.data[at + kk * w + jj];
                    }
                }
                at += k * w;
                j0 += w;
            }
            assert_eq!(seen, b, "k={k} n={n} nr={nr}");
        }
    }

    #[test]
    fn blocked_matches_reference_bitwise_across_tiles() {
        let mut rng = Rng::new(17);
        for (m, k, n) in [(1usize, 1, 1), (3, 5, 7), (8, 16, 64), (13, 9, 70), (32, 24, 130)] {
            let a = fill(&mut rng, m * k);
            let b = fill(&mut rng, k * n);
            let bias = fill(&mut rng, n);
            let mut want = vec![0.0f32; m * n];
            gemm_reference(&a, m, k, &b, n, Some(&bias), &mut want);
            for nr in [1usize, 3, 8, 64] {
                for mr in [1usize, 2, 8, 11] {
                    let pb = PackedB::pack_with_nr(&b, k, n, nr);
                    let mut got = vec![0.0f32; m * n];
                    let mut ctile = vec![0.0f32; mr * nr];
                    gemm_blocked_with(&a, m, k, &pb, Some(&bias), &mut got, mr, &mut ctile);
                    for (x, y) in got.iter().zip(want.iter()) {
                        assert_eq!(x.to_bits(), y.to_bits(), "m={m} k={k} n={n} mr={mr} nr={nr}");
                    }
                }
            }
            // default-tile and no-bias paths
            let pb = PackedB::pack(&b, k, n);
            let mut got = vec![0.0f32; m * n];
            gemm_blocked(&a, m, k, &pb, Some(&bias), &mut got);
            for (x, y) in got.iter().zip(want.iter()) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
            gemm_reference(&a, m, k, &b, n, None, &mut want);
            gemm_blocked(&a, m, k, &pb, None, &mut got);
            for (x, y) in got.iter().zip(want.iter()) {
                assert_eq!(x.to_bits(), y.to_bits(), "no-bias");
            }
        }
    }

    #[test]
    fn narrow_unpacked_matches_reference_bitwise() {
        let mut rng = Rng::new(29);
        for (m, k, n) in [(9usize, 12usize, 2usize), (17, 33, 64), (4, 6, 1)] {
            let a = fill(&mut rng, m * k);
            let b = fill(&mut rng, k * n);
            let bias = fill(&mut rng, n);
            let mut want = vec![0.0f32; m * n];
            let mut got = vec![0.0f32; m * n];
            gemm_reference(&a, m, k, &b, n, Some(&bias), &mut want);
            gemm_blocked_narrow(&a, m, k, &b, n, Some(&bias), &mut got);
            for (x, y) in got.iter().zip(want.iter()) {
                assert_eq!(x.to_bits(), y.to_bits(), "m={m} k={k} n={n}");
            }
        }
    }

    #[test]
    fn repack_reuses_allocation() {
        let mut rng = Rng::new(5);
        let b1 = fill(&mut rng, 12 * 8);
        let b2 = fill(&mut rng, 6 * 10);
        let mut pb = PackedB::empty();
        pb.repack(&b1, 12, 8);
        let cap = pb.data.capacity();
        pb.repack(&b2, 6, 10);
        assert_eq!(pb.data.capacity(), cap, "smaller repack must not reallocate");
        assert_eq!((pb.k(), pb.n()), (6, 10));
    }

    #[test]
    fn rowmajor_lanes_identical_in_both_modes() {
        let _g = MODE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let mut rng = Rng::new(41);
        for (m, k, n) in [(1usize, 3usize, 2usize), (33, 17, 5), (64, 8, 9)] {
            let a = fill(&mut rng, m * k);
            let w = fill(&mut rng, n * k);
            let bias = fill(&mut rng, n);
            let run = |mode: GemmMode| {
                force_gemm_mode(Some(mode));
                let mut out = vec![0.0f32; m * n];
                gemm_rowmajor_lanes(&a, m, k, &w, &bias, n, &mut out);
                force_gemm_mode(None);
                out
            };
            let r = run(GemmMode::Reference);
            let b = run(GemmMode::Blocked);
            for (i, (x, y)) in r.iter().zip(b.iter()).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "m={m} k={k} n={n} at {i}");
                // and each element is the documented closed form
                let (row, col) = (i / n, i % n);
                let want = bias[col]
                    + dot_lanes(&w[col * k..(col + 1) * k], &a[row * k..(row + 1) * k]) as f32;
                assert_eq!(x.to_bits(), want.to_bits());
            }
        }
    }
}
