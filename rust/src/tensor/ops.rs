//! Slice-level kernels for the ZO hot loop.
//!
//! These are the L3 counterparts of the L1 Pallas axpy/reduce kernels: the
//! coordinator uses them for sampler/optimizer state updates (O(d) or
//! O(K d) per step).  Written as simple indexed loops over chunks so LLVM
//! auto-vectorizes them; `perf_hotpath` benches track their throughput.

/// y += a * x
#[inline]
pub fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi += a * *xi;
    }
}

/// out = x + a * d  (out may not alias x or d)
#[inline]
pub fn axpy_into(out: &mut [f32], x: &[f32], a: f32, d: &[f32]) {
    debug_assert_eq!(x.len(), out.len());
    debug_assert_eq!(d.len(), out.len());
    for i in 0..out.len() {
        out[i] = x[i] + a * d[i];
    }
}

#[inline]
pub fn dot(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    // accumulate in f64 to keep alignment statistics stable for large d
    let mut acc = 0.0f64;
    for (a, b) in x.iter().zip(y.iter()) {
        acc += (*a as f64) * (*b as f64);
    }
    acc as f32
}

#[inline]
pub fn nrm2(x: &[f32]) -> f32 {
    let mut acc = 0.0f64;
    for a in x {
        acc += (*a as f64) * (*a as f64);
    }
    acc.sqrt() as f32
}

/// x *= a
#[inline]
pub fn scal(a: f32, x: &mut [f32]) {
    for v in x.iter_mut() {
        *v *= a;
    }
}

/// x /= ||x||; returns the norm.  Leaves x untouched (and returns 0) if the
/// norm underflows.
pub fn normalize(x: &mut [f32]) -> f32 {
    let n = nrm2(x);
    if n > f32::MIN_POSITIVE {
        scal(1.0 / n, x);
        n
    } else {
        0.0
    }
}

/// Cosine of the angle between x and y (0 if either is ~zero).
pub fn cosine(x: &[f32], y: &[f32]) -> f32 {
    let nx = nrm2(x);
    let ny = nrm2(y);
    if nx <= f32::MIN_POSITIVE || ny <= f32::MIN_POSITIVE {
        return 0.0;
    }
    (dot(x, y) / (nx as f64 * ny as f64) as f32).clamp(-1.0, 1.0)
}

/// out = sum_i w[i] * rows[i]  where rows is a K x d row-major matrix.
/// This is the REINFORCE mu-gradient reduce (Algorithm 2, line 6).
pub fn weighted_row_sum(rows: &[f32], d: usize, w: &[f32], out: &mut [f32]) {
    assert_eq!(rows.len(), w.len() * d, "rows must be K x d");
    assert_eq!(out.len(), d);
    out.iter_mut().for_each(|v| *v = 0.0);
    for (k, wk) in w.iter().enumerate() {
        if *wk != 0.0 {
            axpy(*wk, &rows[k * d..(k + 1) * d], out);
        }
    }
}

/// Elementwise sign (0.0 stays 0.0) — used by JAGUAR SignSGD.
#[inline]
pub fn sign_into(out: &mut [f32], x: &[f32]) {
    debug_assert_eq!(x.len(), out.len());
    for i in 0..out.len() {
        out[i] = if x[i] > 0.0 {
            1.0
        } else if x[i] < 0.0 {
            -1.0
        } else {
            0.0
        };
    }
}

/// Numerically-stable softmax over a small slice (eval-side utility).
pub fn softmax_inplace(x: &mut [f32]) {
    let m = x.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f32;
    for v in x.iter_mut() {
        *v = (*v - m).exp();
        sum += *v;
    }
    if sum > 0.0 {
        scal(1.0 / sum, x);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axpy_basic() {
        let x = [1.0f32, 2.0, 3.0];
        let mut y = [10.0f32, 20.0, 30.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [12.0, 24.0, 36.0]);
    }

    #[test]
    fn axpy_into_basic() {
        let x = [1.0f32, 2.0];
        let d = [10.0f32, -10.0];
        let mut out = [0.0f32; 2];
        axpy_into(&mut out, &x, 0.5, &d);
        assert_eq!(out, [6.0, -3.0]);
    }

    #[test]
    fn dot_and_norm() {
        let x = [3.0f32, 4.0];
        assert_eq!(dot(&x, &x), 25.0);
        assert_eq!(nrm2(&x), 5.0);
    }

    #[test]
    fn normalize_unit() {
        let mut x = [3.0f32, 4.0];
        let n = normalize(&mut x);
        assert_eq!(n, 5.0);
        assert!((nrm2(&x) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn normalize_zero_vector_safe() {
        let mut x = [0.0f32; 4];
        assert_eq!(normalize(&mut x), 0.0);
        assert_eq!(x, [0.0; 4]);
    }

    #[test]
    fn cosine_bounds() {
        let x = [1.0f32, 0.0];
        let y = [1.0f32, 1.0];
        let c = cosine(&x, &y);
        assert!((c - std::f32::consts::FRAC_1_SQRT_2).abs() < 1e-6);
        assert_eq!(cosine(&x, &x), 1.0);
        assert_eq!(cosine(&x, &[0.0, 0.0]), 0.0);
    }

    #[test]
    fn weighted_row_sum_matches_manual() {
        let rows = [1.0f32, 0.0, 0.0, 1.0, 1.0, 1.0]; // 3 rows x d=2
        let w = [1.0f32, 2.0, -1.0];
        let mut out = [0.0f32; 2];
        weighted_row_sum(&rows, 2, &w, &mut out);
        assert_eq!(out, [0.0, 1.0]);
    }

    #[test]
    fn sign_matches() {
        let x = [-2.0f32, 0.0, 5.0];
        let mut out = [9.0f32; 3];
        sign_into(&mut out, &x);
        assert_eq!(out, [-1.0, 0.0, 1.0]);
    }

    #[test]
    fn softmax_sums_to_one() {
        let mut x = [1.0f32, 2.0, 3.0];
        softmax_inplace(&mut x);
        let s: f32 = x.iter().sum();
        assert!((s - 1.0).abs() < 1e-6);
        assert!(x[2] > x[1] && x[1] > x[0]);
    }
}
