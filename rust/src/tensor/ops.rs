//! Slice-level kernels for the ZO hot loop.
//!
//! These are the L3 counterparts of the L1 Pallas axpy/reduce kernels: the
//! coordinator uses them for sampler/optimizer state updates (O(d) or
//! O(K d) per step).  The axpy family dispatches through the
//! [`super::lanes`] kernels (DESIGN.md §14): fused `mul_add` arithmetic
//! whose scalar and avx2+fma wide forms are bit-identical, selected by
//! `ZO_LANES`; `perf_hotpath` benches the two forms side by side.
//!
//! The K-probe batching refactor adds two blocked kernels operating on the
//! row-major K x d probe matrix directly:
//! * [`axpy_k`] — fused multi-direction axpy, `y += sum_i a[i] * rows[i]`,
//!   one blocked pass instead of K full sweeps of `y`;
//! * [`probe_combine`] — the gemv-style probe reduce `g = sum_i w[i] *
//!   dirs[i]` used by the estimators' `consume` phase and the LDSD
//!   REINFORCE update.
//!
//! The shard-parallel engine adds `_ctx` variants ([`axpy_k_ctx`],
//! [`probe_combine_ctx`], [`axpy_into_ctx`]) that process disjoint column
//! shards of the output concurrently on an [`ExecContext`].  Per output
//! element the arithmetic and its order are exactly the serial kernel's
//! (rows accumulate in row order within fixed cache blocks), and shard
//! boundaries depend only on [`ExecContext::shard_len`], so the parallel
//! variants are bitwise identical to their serial references for any
//! worker count — `tests/properties.rs` pins this across random shapes
//! and shard lengths.

use super::lanes;
use crate::exec::ExecContext;

/// `y += a * x`, fused (`y[i] = a.mul_add(x[i], y[i])`).
///
/// ```
/// use zo_ldsd::tensor::axpy;
///
/// let x = [1.0f32, 2.0, 3.0];
/// let mut y = [10.0f32, 20.0, 30.0];
/// axpy(2.0, &x, &mut y);
/// assert_eq!(y, [12.0, 24.0, 36.0]);
/// ```
#[inline]
pub fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    lanes::fma_axpy(a, x, y);
}

/// `out = x + a * d`, fused (out may not alias x or d).
#[inline]
pub fn axpy_into(out: &mut [f32], x: &[f32], a: f32, d: &[f32]) {
    debug_assert_eq!(x.len(), out.len());
    debug_assert_eq!(d.len(), out.len());
    lanes::fma_axpy_into(out, x, a, d);
}

/// Column-block size for the multi-row kernels: the `y`/`g` block stays in
/// L1 while all K probe rows stream through it once.
const BLOCK: usize = 1024;

/// Fused multi-direction axpy over a row-major K x d matrix:
/// `y += sum_i a[i] * xs[i*d .. (i+1)*d]` with `d = y.len()`.
///
/// Equivalent to K separate [`axpy`] calls, but blocked so each column
/// block of `y` is loaded into cache once per step instead of K times —
/// the difference dominates once `K * d` floats exceed L2.
///
/// ```
/// use zo_ldsd::tensor::axpy_k;
///
/// let rows = [1.0f32, 0.0, 0.0, 1.0]; // 2 rows x d=2
/// let mut y = [10.0f32, 10.0];
/// axpy_k(&[2.0, -1.0], &rows, &mut y);
/// assert_eq!(y, [12.0, 9.0]);
/// ```
pub fn axpy_k(a: &[f32], xs: &[f32], y: &mut [f32]) {
    let d = y.len();
    assert_eq!(xs.len(), a.len() * d, "xs must be K x d");
    axpy_k_cols(a, xs, d, 0, y);
}

/// The blocked `axpy_k` loop restricted to the column window
/// `[col0, col0 + yb.len())` of the full K x `d` matrix, accumulating into
/// the window slice `yb`.  Shared by the serial kernel (whole range) and
/// the shard-parallel variant (one shard per call); per column the row
/// accumulation order is identical either way.
fn axpy_k_cols(a: &[f32], xs: &[f32], d: usize, col0: usize, yb: &mut [f32]) {
    let col_end = col0 + yb.len();
    let mut start = col0;
    while start < col_end {
        let end = (start + BLOCK).min(col_end);
        for (k, ak) in a.iter().enumerate() {
            if *ak == 0.0 {
                continue;
            }
            let row = &xs[k * d + start..k * d + end];
            let yw = &mut yb[start - col0..end - col0];
            lanes::fma_axpy(*ak, row, yw);
        }
        start = end;
    }
}

/// Shard-parallel [`axpy_k`]: disjoint column shards of `y` accumulate
/// concurrently, each with the serial kernel's blocked row-order loop —
/// bitwise identical to [`axpy_k`] for any worker count and shard length.
pub fn axpy_k_ctx(ctx: &ExecContext, a: &[f32], xs: &[f32], y: &mut [f32]) {
    let d = y.len();
    assert_eq!(xs.len(), a.len() * d, "xs must be K x d");
    ctx.for_each_shard_mut(y, |_, start, yb| {
        axpy_k_cols(a, xs, d, start, yb);
    });
}

/// `dot(x, y)` with an f64 accumulator (keeps alignment statistics stable
/// for large d).
///
/// ```
/// use zo_ldsd::tensor::dot;
///
/// assert_eq!(dot(&[3.0, 4.0], &[3.0, 4.0]), 25.0);
/// assert_eq!(dot(&[1.0, 0.0], &[0.0, 1.0]), 0.0);
/// ```
#[inline]
pub fn dot(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    let mut acc = 0.0f64;
    for (a, b) in x.iter().zip(y.iter()) {
        acc += (*a as f64) * (*b as f64);
    }
    acc as f32
}

/// Euclidean norm `||x||` (f64 accumulator).
#[inline]
pub fn nrm2(x: &[f32]) -> f32 {
    let mut acc = 0.0f64;
    for a in x {
        acc += (*a as f64) * (*a as f64);
    }
    acc.sqrt() as f32
}

/// `x *= a`
#[inline]
pub fn scal(a: f32, x: &mut [f32]) {
    for v in x.iter_mut() {
        *v *= a;
    }
}

/// `x /= ||x||`; returns the norm.  Leaves x untouched (and returns 0) if the
/// norm underflows.
pub fn normalize(x: &mut [f32]) -> f32 {
    let n = nrm2(x);
    if n > f32::MIN_POSITIVE {
        scal(1.0 / n, x);
        n
    } else {
        0.0
    }
}

/// Cosine of the angle between x and y (0 if either is ~zero).
pub fn cosine(x: &[f32], y: &[f32]) -> f32 {
    let nx = nrm2(x);
    let ny = nrm2(y);
    if nx <= f32::MIN_POSITIVE || ny <= f32::MIN_POSITIVE {
        return 0.0;
    }
    (dot(x, y) / (nx as f64 * ny as f64) as f32).clamp(-1.0, 1.0)
}

/// Probe-matrix reduce: `g = sum_i w[i] * dirs[i*d .. (i+1)*d]` over a
/// row-major K x d direction matrix — a gemv (`dirs^T w`) written as a
/// blocked loop.
///
/// This is the combine step of the batched K-probe estimation path: the
/// finite-difference (or REINFORCE-advantage) weights of all K probes are
/// applied to the shared direction matrix in one pass (Algorithm 2 lines
/// 5-6).
///
/// ```
/// use zo_ldsd::tensor::probe_combine;
///
/// let dirs = [1.0f32, 0.0, 0.0, 1.0, 1.0, 1.0]; // 3 rows x d=2
/// let mut g = [99.0f32, 99.0];
/// probe_combine(&dirs, 2, &[1.0, 2.0, -1.0], &mut g);
/// assert_eq!(g, [0.0, 1.0]);
/// ```
pub fn probe_combine(dirs: &[f32], d: usize, w: &[f32], g: &mut [f32]) {
    assert_eq!(dirs.len(), w.len() * d, "dirs must be K x d");
    assert_eq!(g.len(), d);
    g.iter_mut().for_each(|v| *v = 0.0);
    axpy_k(w, dirs, g);
}

/// Shard-parallel [`probe_combine`]: each column shard of `g` is zeroed
/// and reduced over the K probe rows in one pass, shards concurrent.  The
/// per-column reduction over rows runs in row order (the serial kernel's
/// order), so the result is bitwise identical to [`probe_combine`].
pub fn probe_combine_ctx(ctx: &ExecContext, dirs: &[f32], d: usize, w: &[f32], g: &mut [f32]) {
    assert_eq!(dirs.len(), w.len() * d, "dirs must be K x d");
    assert_eq!(g.len(), d);
    ctx.for_each_shard_mut(g, |_, start, gb| {
        gb.iter_mut().for_each(|v| *v = 0.0);
        axpy_k_cols(w, dirs, d, start, gb);
    });
}

/// Fused perturb→evaluate pass for the streamed probe engine: calls
/// `f(i, tau.mul_add(v[i], x[i]))` for every index of the window without
/// materializing the perturbed vector.  The perturbation arithmetic is
/// the fused expression the materialized `loss_k` kernels use
/// ([`lanes::fma_axpy_into`]), so oracles evaluating through this on
/// regenerated probe shards produce bitwise the same losses as the slice
/// path (DESIGN.md §10).  z values are computed in vectorizable chunks,
/// then delivered to the visitor in index order — elementwise arithmetic,
/// so chunking cannot change any bit.
#[inline]
pub fn perturb_eval<F: FnMut(usize, f32)>(x: &[f32], tau: f32, v: &[f32], mut f: F) {
    debug_assert_eq!(x.len(), v.len());
    const CHUNK: usize = 256;
    let mut z = [0.0f32; CHUNK];
    let mut start = 0;
    while start < x.len() {
        let m = (x.len() - start).min(CHUNK);
        lanes::fma_perturb_fill(&x[start..start + m], tau, &v[start..start + m], &mut z[..m]);
        for (j, zj) in z[..m].iter().enumerate() {
            f(start + j, *zj);
        }
        start += m;
    }
}

/// Seed-replay update kernel: `y += sum_i w[i] * row_i` over one column
/// window, where each row's values are regenerated on demand into
/// `scratch` by `fill(i, window)` instead of being read from a stored
/// matrix.  Rows accumulate in row order and zero weights are skipped —
/// exactly [`axpy_k`]'s per-element behavior, so the streamed update is
/// bitwise identical to the materialized one.
pub fn replay_axpy<F: FnMut(usize, &mut [f32])>(
    w: &[f32],
    scratch: &mut [f32],
    y: &mut [f32],
    mut fill: F,
) {
    let n = y.len();
    debug_assert!(scratch.len() >= n, "scratch must cover the column window");
    for (i, wi) in w.iter().enumerate() {
        if *wi == 0.0 {
            continue;
        }
        let row = &mut scratch[..n];
        fill(i, row);
        lanes::fma_axpy(*wi, row, y);
    }
}

/// Shard-parallel [`axpy_into`]: `out = x + a * d`, elementwise over
/// disjoint shards — bitwise identical to the serial kernel.
pub fn axpy_into_ctx(ctx: &ExecContext, out: &mut [f32], x: &[f32], a: f32, d: &[f32]) {
    assert_eq!(x.len(), out.len());
    assert_eq!(d.len(), out.len());
    ctx.for_each_shard_mut(out, |_, start, ob| {
        let xs = &x[start..start + ob.len()];
        let ds = &d[start..start + ob.len()];
        lanes::fma_axpy_into(ob, xs, a, ds);
    });
}

/// Elementwise sign (0.0 stays 0.0) — used by JAGUAR SignSGD.
#[inline]
pub fn sign_into(out: &mut [f32], x: &[f32]) {
    debug_assert_eq!(x.len(), out.len());
    for i in 0..out.len() {
        out[i] = if x[i] > 0.0 {
            1.0
        } else if x[i] < 0.0 {
            -1.0
        } else {
            0.0
        };
    }
}

/// Numerically-stable softmax over a small slice (eval-side utility).
pub fn softmax_inplace(x: &mut [f32]) {
    let m = x.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f32;
    for v in x.iter_mut() {
        *v = (*v - m).exp();
        sum += *v;
    }
    if sum > 0.0 {
        scal(1.0 / sum, x);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axpy_basic() {
        let x = [1.0f32, 2.0, 3.0];
        let mut y = [10.0f32, 20.0, 30.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [12.0, 24.0, 36.0]);
    }

    #[test]
    fn axpy_into_basic() {
        let x = [1.0f32, 2.0];
        let d = [10.0f32, -10.0];
        let mut out = [0.0f32; 2];
        axpy_into(&mut out, &x, 0.5, &d);
        assert_eq!(out, [6.0, -3.0]);
    }

    #[test]
    fn dot_and_norm() {
        let x = [3.0f32, 4.0];
        assert_eq!(dot(&x, &x), 25.0);
        assert_eq!(nrm2(&x), 5.0);
    }

    #[test]
    fn normalize_unit() {
        let mut x = [3.0f32, 4.0];
        let n = normalize(&mut x);
        assert_eq!(n, 5.0);
        assert!((nrm2(&x) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn normalize_zero_vector_safe() {
        let mut x = [0.0f32; 4];
        assert_eq!(normalize(&mut x), 0.0);
        assert_eq!(x, [0.0; 4]);
    }

    #[test]
    fn cosine_bounds() {
        let x = [1.0f32, 0.0];
        let y = [1.0f32, 1.0];
        let c = cosine(&x, &y);
        assert!((c - std::f32::consts::FRAC_1_SQRT_2).abs() < 1e-6);
        assert_eq!(cosine(&x, &x), 1.0);
        assert_eq!(cosine(&x, &[0.0, 0.0]), 0.0);
    }

    #[test]
    fn probe_combine_matches_manual() {
        let rows = [1.0f32, 0.0, 0.0, 1.0, 1.0, 1.0]; // 3 rows x d=2
        let w = [1.0f32, 2.0, -1.0];
        let mut out = [0.0f32; 2];
        probe_combine(&rows, 2, &w, &mut out);
        assert_eq!(out, [0.0, 1.0]);
    }

    #[test]
    fn axpy_k_matches_k_axpys() {
        // axpy_k over a K x d matrix must agree with K scalar axpy calls,
        // including across the BLOCK boundary.
        let d = BLOCK + 37;
        let k = 4;
        let rows: Vec<f32> = (0..k * d).map(|i| ((i % 13) as f32) - 6.0).collect();
        let a = [0.5f32, -1.0, 0.0, 2.0];
        let mut fused = vec![1.0f32; d];
        let mut looped = vec![1.0f32; d];
        axpy_k(&a, &rows, &mut fused);
        for i in 0..k {
            axpy(a[i], &rows[i * d..(i + 1) * d], &mut looped);
        }
        assert_eq!(fused, looped);
    }

    #[test]
    fn probe_combine_zeroes_output_first() {
        let dirs = [1.0f32, 1.0];
        let mut g = [5.0f32, -5.0];
        probe_combine(&dirs, 2, &[3.0], &mut g);
        assert_eq!(g, [3.0, 3.0]);
    }

    #[test]
    fn probe_combine_empty_k_gives_zero() {
        let mut g = [7.0f32; 3];
        probe_combine(&[], 3, &[], &mut g);
        assert_eq!(g, [0.0; 3]);
    }

    #[test]
    fn ctx_kernels_bitwise_match_serial_across_thread_counts() {
        // same shapes as axpy_k_matches_k_axpys, plus odd shard lengths so
        // shard and cache-block boundaries are misaligned on purpose
        let d = BLOCK + 37;
        let k = 4;
        let rows: Vec<f32> = (0..k * d).map(|i| ((i % 13) as f32) - 6.0).collect();
        let a = [0.5f32, -1.0, 0.0, 2.0];
        let x: Vec<f32> = (0..d).map(|i| (i % 7) as f32 * 0.25).collect();
        let mut y_serial = vec![1.0f32; d];
        axpy_k(&a, &rows, &mut y_serial);
        let mut g_serial = vec![0.0f32; d];
        probe_combine(&rows, d, &a, &mut g_serial);
        let mut o_serial = vec![0.0f32; d];
        axpy_into(&mut o_serial, &x, 0.3, &g_serial);
        for threads in [1usize, 3, 8] {
            for shard_len in [33usize, BLOCK, d + 1] {
                let ctx = ExecContext::new(threads).with_shard_len(shard_len);
                let mut y = vec![1.0f32; d];
                axpy_k_ctx(&ctx, &a, &rows, &mut y);
                assert_eq!(y, y_serial, "axpy_k t={threads} sl={shard_len}");
                let mut g = vec![9.0f32; d];
                probe_combine_ctx(&ctx, &rows, d, &a, &mut g);
                assert_eq!(g, g_serial, "probe_combine t={threads} sl={shard_len}");
                let mut o = vec![0.0f32; d];
                axpy_into_ctx(&ctx, &mut o, &x, 0.3, &g);
                assert_eq!(o, o_serial, "axpy_into t={threads} sl={shard_len}");
            }
        }
    }

    #[test]
    fn perturb_eval_matches_axpy_into() {
        let x = [1.0f32, -2.0, 0.5, 3.25];
        let v = [0.5f32, 1.5, -4.0, 0.0];
        let tau = 1e-3f32;
        let mut out = [0.0f32; 4];
        axpy_into(&mut out, &x, tau, &v);
        let mut streamed = [0.0f32; 4];
        perturb_eval(&x, tau, &v, |i, z| streamed[i] = z);
        for (a, b) in out.iter().zip(streamed.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn replay_axpy_bitwise_matches_axpy_k() {
        // regeneration closure serves rows of a reference matrix; the
        // replayed accumulation must be bit-for-bit the fused kernel's
        let d = BLOCK + 13;
        let k = 4;
        let rows: Vec<f32> = (0..k * d).map(|i| ((i % 11) as f32) * 0.3 - 1.5).collect();
        let w = [0.25f32, 0.0, -1.0, 0.75];
        let mut fused = vec![0.5f32; d];
        axpy_k(&w, &rows, &mut fused);
        let mut replayed = vec![0.5f32; d];
        let mut scratch = vec![0.0f32; d];
        let mut fills = 0usize;
        replay_axpy(&w, &mut scratch, &mut replayed, |i, out| {
            fills += 1;
            out.copy_from_slice(&rows[i * d..(i + 1) * d]);
        });
        assert_eq!(fills, 3, "zero-weight rows must not be regenerated");
        for (a, b) in fused.iter().zip(replayed.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn sign_matches() {
        let x = [-2.0f32, 0.0, 5.0];
        let mut out = [9.0f32; 3];
        sign_into(&mut out, &x);
        assert_eq!(out, [-1.0, 0.0, 1.0]);
    }

    #[test]
    fn softmax_sums_to_one() {
        let mut x = [1.0f32, 2.0, 3.0];
        softmax_inplace(&mut x);
        let s: f32 = x.iter().sum();
        assert!((s - 1.0).abs() < 1e-6);
        assert!(x[2] > x[1] && x[1] > x[0]);
    }
}
