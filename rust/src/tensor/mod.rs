//! Host-side f32 vector/matrix math (replaces ndarray for the coordinator).
//!
//! The ZO hot loop is O(d) vector algebra: axpy, dot, norms, scaling.
//! Everything here operates on plain `&[f32]` slices so optimizer state and
//! parameter stores can share buffers without copies; the `Vector`
//! new-type adds checked construction and convenience ops on top.

pub mod gemm;
pub mod lanes;
mod ops;
pub mod qstore;
mod vector;

pub use gemm::{effective_gemm_mode, force_gemm_mode, GemmMode, PackedB};
pub use lanes::{dot_lanes, LaneMode};
pub use ops::*;
pub use qstore::{ParamStore, ParamStoreMode};
pub use vector::Vector;

/// A dense row-major matrix view used by the toy oracles (linreg / logreg).
#[derive(Clone, Debug)]
pub struct Matrix {
    /// Row count.
    pub rows: usize,
    /// Column count.
    pub cols: usize,
    /// Row-major storage (rows x cols).
    pub data: Vec<f32>,
}

impl Matrix {
    /// All-zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Wrap row-major storage (size-checked).
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "matrix size mismatch");
        Self { rows, cols, data }
    }

    /// Borrow row `r`.
    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// y = A x  (A: rows x cols, x: cols)
    pub fn matvec(&self, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        for r in 0..self.rows {
            y[r] = dot(self.row(r), x);
        }
    }

    /// y = A^T x  (x: rows, y: cols)
    pub fn matvec_t(&self, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), self.rows);
        assert_eq!(y.len(), self.cols);
        y.iter_mut().for_each(|v| *v = 0.0);
        for r in 0..self.rows {
            let xr = x[r];
            if xr != 0.0 {
                axpy(xr, self.row(r), y);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matvec_matches_manual() {
        let a = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let x = [1.0f32, 0.5, -1.0];
        let mut y = [0.0f32; 2];
        a.matvec(&x, &mut y);
        assert_eq!(y, [-1.0, 0.5]);
    }

    #[test]
    fn matvec_t_matches_manual() {
        let a = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let x = [2.0f32, -1.0];
        let mut y = [0.0f32; 3];
        a.matvec_t(&x, &mut y);
        assert_eq!(y, [-2.0, -1.0, 0.0]);
    }

    #[test]
    #[should_panic]
    fn from_vec_size_checked() {
        let _ = Matrix::from_vec(2, 2, vec![0.0; 3]);
    }
}
