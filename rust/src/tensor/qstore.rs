//! Quantized parameter storage for forward-only training (DESIGN.md §14).
//!
//! ZO never backpropagates through the weights, so the resident parameter
//! vector only ever feeds forward evaluations at `x + tau * v` — which
//! makes low-precision storage with on-the-fly dequantization viable.
//! [`ParamStore`] keeps the iterate in one of three modes:
//!
//! * **f32** — plain `Vec<f32>`, the default; zero behavior change.
//! * **f16** — IEEE binary16 with round-to-nearest-even encode.  Decode
//!   is *exact* (every f16 value is an f32 value), so all downstream
//!   arithmetic on a dequantized f16 store is bit-identical to running
//!   the same f32 kernels on the dequantized values — 2 bytes/param
//!   resident.
//! * **int8** — symmetric 8-bit blocks ([`QBLOCK`] params per block) with
//!   **power-of-two** per-block scales.  Dequant `q * 2^e` is exact
//!   (a ≤7-bit-magnitude integer times a power of two always fits an f32
//!   significand), and requantizing a dequantized store reproduces it
//!   bit-for-bit: the admissible exponent can only shrink or stay put on
//!   the dequant image, and `q * 2^(e-e')` is an exact integer, so the
//!   rounded quantize recovers the same codes.  That is what makes
//!   snapshot → restore → continue bitwise reproducible — snapshots store
//!   the dequantized f32 image and restore by requantization.
//!   ~1.06 bytes/param resident (1 + 4/[`QBLOCK`]).
//!
//! Quantization is *lossy at store time* (`store_from` rounds), but every
//! read path — [`ParamStore::dequant_into`], the fused
//! [`ParamStore::perturb_into`] — produces identical f32 bits for the
//! same stored state at any thread count, lane mode, and probe-storage
//! mode.  Resident bytes register with [`crate::metrics::param_tracker`]
//! for the memory-table benches.

use super::lanes;

/// Params per int8 quantization block (one f32 scale per block).
pub const QBLOCK: usize = 64;

/// Floor for int8 block scales (2^-120): keeps `1/s` exact and `q * s`
/// normal for every code, so dequantization never rounds.  Blocks whose
/// max |x| sits below `127 * 2^-120` quantize on a coarser grid, losing
/// only values that are numerically zero for training purposes.
pub const MIN_SCALE: f32 = f32::from_bits(0x0380_0000);

/// Storage mode for the resident parameter vector.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ParamStoreMode {
    /// Full-precision f32 (default).
    F32,
    /// IEEE binary16, round-to-nearest-even encode, exact decode.
    F16,
    /// Symmetric int8 blocks with power-of-two scales, exact dequant.
    Int8,
}

impl ParamStoreMode {
    /// Parse `"f32"` / `"f16"` / `"int8"`.
    pub fn parse(s: &str) -> Option<ParamStoreMode> {
        match s {
            "f32" => Some(ParamStoreMode::F32),
            "f16" => Some(ParamStoreMode::F16),
            "int8" => Some(ParamStoreMode::Int8),
            _ => None,
        }
    }

    /// The label used by `--param-store`, `ZO_PARAM_STORE` and snapshot
    /// fingerprints.
    pub fn label(&self) -> &'static str {
        match self {
            ParamStoreMode::F32 => "f32",
            ParamStoreMode::F16 => "f16",
            ParamStoreMode::Int8 => "int8",
        }
    }
}

/// Convert an f32 to IEEE binary16 bits with round-to-nearest-even
/// (overflow → ±Inf, NaN → quiet NaN, subnormals rounded exactly).
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let b = x.to_bits();
    let sign = ((b >> 16) & 0x8000) as u16;
    let exp = ((b >> 23) & 0xff) as i32;
    let man = b & 0x007f_ffff;
    if exp == 0xff {
        if man == 0 {
            return sign | 0x7c00; // Inf
        }
        return sign | 0x7e00; // quiet NaN
    }
    let half_exp = exp - 127 + 15;
    if half_exp >= 0x1f {
        return sign | 0x7c00; // overflow -> Inf
    }
    if half_exp <= 0 {
        if half_exp < -10 {
            return sign; // below half the smallest subnormal -> signed zero
        }
        // subnormal half: shift the 24-bit significand (implicit bit set)
        let man24 = man | 0x0080_0000;
        let shift = (14 - half_exp) as u32;
        let half_man = man24 >> shift;
        let round_bit = 1u32 << (shift - 1);
        let sticky = man24 & (round_bit - 1);
        let lsb = half_man & 1;
        let mut h = half_man as u16;
        if man24 & round_bit != 0 && (sticky != 0 || lsb != 0) {
            h += 1; // may carry into exp = 1: the smallest normal, correct
        }
        return sign | h;
    }
    let half_man = (man >> 13) & 0x03ff;
    let mut h = (sign as u32) | ((half_exp as u32) << 10) | half_man;
    let round_bit = 0x0000_1000u32;
    let sticky = man & (round_bit - 1);
    let lsb = half_man & 1;
    if man & round_bit != 0 && (sticky != 0 || lsb != 0) {
        h += 1; // mantissa carry may bump the exponent (up to Inf): correct
    }
    h as u16
}

/// Decode IEEE binary16 bits to f32 — exact for every input.
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = (h >> 10) & 0x1f;
    let man = (h & 0x03ff) as u32;
    if exp == 0 {
        // subnormal: man * 2^-24, exact in f32
        let mag = man as f32 * f32::from_bits(0x3380_0000); // 2^-24
        return if sign != 0 { -mag } else { mag };
    }
    if exp == 0x1f {
        if man == 0 {
            return f32::from_bits(sign | 0x7f80_0000);
        }
        return f32::from_bits(sign | 0x7fc0_0000 | (man << 13));
    }
    f32::from_bits(sign | ((exp as u32 + 112) << 23) | (man << 13))
}

/// Smallest power-of-two scale `s >= MIN_SCALE` with `127 * s >= max_abs`
/// (1.0 for zero or non-finite blocks).
fn block_scale(max_abs: f32) -> f32 {
    if !max_abs.is_finite() || max_abs <= 0.0 {
        return 1.0;
    }
    let mut s = 1.0f32;
    while 127.0 * s < max_abs {
        s *= 2.0;
    }
    while s > MIN_SCALE && 127.0 * (s * 0.5) >= max_abs {
        s *= 0.5;
    }
    s
}

/// Quantize `xs` into pre-sized code/scale buffers (shared by the
/// constructor and in-place requantization).
fn quantize_int8(xs: &[f32], q: &mut [i8], scales: &mut [f32]) {
    debug_assert_eq!(q.len(), xs.len());
    debug_assert_eq!(scales.len(), (xs.len() + QBLOCK - 1) / QBLOCK);
    for (bi, block) in xs.chunks(QBLOCK).enumerate() {
        let max_abs = block.iter().fold(0.0f32, |m, x| m.max(x.abs()));
        let s = block_scale(max_abs);
        scales[bi] = s;
        let inv = 1.0 / s; // s is a power of two: inv is exact
        for (j, x) in block.iter().enumerate() {
            let code = (x * inv).round().clamp(-127.0, 127.0);
            q[bi * QBLOCK + j] = code as i8;
        }
    }
}

enum Repr {
    F32(Vec<f32>),
    F16(Vec<u16>),
    Int8 { q: Vec<i8>, scales: Vec<f32> },
}

/// The resident parameter vector in one of three storage modes, with
/// exact dequantization and a fused on-the-fly perturb kernel.  Resident
/// bytes are registered with [`crate::metrics::param_tracker`] for the
/// store's lifetime.
pub struct ParamStore {
    repr: Repr,
    tracked: usize,
}

impl ParamStore {
    /// Quantize (or copy) `xs` into a fresh store of the given mode.
    pub fn from_f32(mode: ParamStoreMode, xs: &[f32]) -> Self {
        let repr = match mode {
            ParamStoreMode::F32 => Repr::F32(xs.to_vec()),
            ParamStoreMode::F16 => Repr::F16(xs.iter().map(|x| f32_to_f16_bits(*x)).collect()),
            ParamStoreMode::Int8 => {
                let nblocks = (xs.len() + QBLOCK - 1) / QBLOCK;
                let mut q = vec![0i8; xs.len()];
                let mut scales = vec![1.0f32; nblocks];
                quantize_int8(xs, &mut q, &mut scales);
                Repr::Int8 { q, scales }
            }
        };
        let mut store = Self { repr, tracked: 0 };
        store.tracked = store.resident_bytes();
        crate::metrics::param_tracker().add(store.tracked);
        store
    }

    /// The store's mode.
    pub fn mode(&self) -> ParamStoreMode {
        match &self.repr {
            Repr::F32(_) => ParamStoreMode::F32,
            Repr::F16(_) => ParamStoreMode::F16,
            Repr::Int8 { .. } => ParamStoreMode::Int8,
        }
    }

    /// Number of parameters stored.
    pub fn len(&self) -> usize {
        match &self.repr {
            Repr::F32(v) => v.len(),
            Repr::F16(v) => v.len(),
            Repr::Int8 { q, .. } => q.len(),
        }
    }

    /// True when the store holds no parameters.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Resident bytes of the stored representation (data + scales).
    pub fn resident_bytes(&self) -> usize {
        match &self.repr {
            Repr::F32(v) => v.len() * 4,
            Repr::F16(v) => v.len() * 2,
            Repr::Int8 { q, scales } => q.len() + scales.len() * 4,
        }
    }

    /// Borrow the f32 slice (f32 mode only — quantized stores have no
    /// resident f32 image; use [`ParamStore::dequant_into`]).
    pub fn as_f32(&self) -> &[f32] {
        match &self.repr {
            Repr::F32(v) => v,
            _ => panic!(
                "parameter store is {}-quantized: no resident f32 slice \
                 (use params_into / dequant_into)",
                self.mode().label()
            ),
        }
    }

    /// Mutably borrow the f32 slice (f32 mode only).
    pub fn as_f32_mut(&mut self) -> &mut [f32] {
        match &mut self.repr {
            Repr::F32(v) => v,
            _ => panic!(
                "parameter store is {}-quantized: no resident f32 slice \
                 (use params_into / dequant_into)",
                self.mode().label()
            ),
        }
    }

    /// Dequantize the window starting at `start` into `out` (exact for
    /// f16/int8 by construction).
    pub fn dequant_range_into(&self, start: usize, out: &mut [f32]) {
        match &self.repr {
            Repr::F32(v) => out.copy_from_slice(&v[start..start + out.len()]),
            Repr::F16(v) => {
                for (i, o) in out.iter_mut().enumerate() {
                    *o = f16_bits_to_f32(v[start + i]);
                }
            }
            Repr::Int8 { q, scales } => {
                for (i, o) in out.iter_mut().enumerate() {
                    let idx = start + i;
                    *o = q[idx] as f32 * scales[idx / QBLOCK];
                }
            }
        }
    }

    /// Dequantize the whole store into `out` (must be `len()` long).
    pub fn dequant_into(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.len());
        self.dequant_range_into(0, out);
    }

    /// Fused perturb on the window at `start`:
    /// `out[i] = tau.mul_add(v[i], dequant(start + i))` — bitwise equal
    /// to dequantizing the window and calling [`lanes::fma_axpy_into`],
    /// because the dequantized f32 values are identical and the fma is
    /// the same kernel; the store is never materialized as f32 in full.
    pub fn perturb_range_into(&self, start: usize, tau: f32, v: &[f32], out: &mut [f32]) {
        assert_eq!(v.len(), out.len());
        match &self.repr {
            Repr::F32(x) => lanes::fma_axpy_into(out, &x[start..start + out.len()], tau, v),
            _ => {
                const CHUNK: usize = 256;
                let mut dq = [0.0f32; CHUNK];
                let mut off = 0;
                while off < out.len() {
                    let m = (out.len() - off).min(CHUNK);
                    self.dequant_range_into(start + off, &mut dq[..m]);
                    lanes::fma_axpy_into(&mut out[off..off + m], &dq[..m], tau, &v[off..off + m]);
                    off += m;
                }
            }
        }
    }

    /// Fused perturb over the whole store: `out = dequant(x) + tau * v`.
    pub fn perturb_into(&self, tau: f32, v: &[f32], out: &mut [f32]) {
        assert_eq!(v.len(), self.len());
        assert_eq!(out.len(), self.len());
        self.perturb_range_into(0, tau, v, out);
    }

    /// Requantize `xs` into the existing representation (same length,
    /// in place — no allocation, tracked bytes unchanged).  On the image
    /// of [`ParamStore::dequant_into`] this is an exact round-trip: the
    /// store is reproduced bit-for-bit.
    pub fn store_from(&mut self, xs: &[f32]) {
        assert_eq!(xs.len(), self.len());
        match &mut self.repr {
            Repr::F32(v) => v.copy_from_slice(xs),
            Repr::F16(v) => {
                for (h, x) in v.iter_mut().zip(xs.iter()) {
                    *h = f32_to_f16_bits(*x);
                }
            }
            Repr::Int8 { q, scales } => quantize_int8(xs, q, scales),
        }
    }

    /// Rebuild the store in a (possibly different) mode, quantizing from
    /// the current dequantized values.
    pub fn convert(&self, mode: ParamStoreMode) -> ParamStore {
        let mut tmp = vec![0.0f32; self.len()];
        self.dequant_into(&mut tmp);
        ParamStore::from_f32(mode, &tmp)
    }
}

impl Drop for ParamStore {
    fn drop(&mut self) {
        crate::metrics::param_tracker().sub(self.tracked);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn f16_spot_values() {
        assert_eq!(f32_to_f16_bits(0.0), 0x0000);
        assert_eq!(f32_to_f16_bits(-0.0), 0x8000);
        assert_eq!(f32_to_f16_bits(1.0), 0x3c00);
        assert_eq!(f32_to_f16_bits(-2.0), 0xc000);
        assert_eq!(f32_to_f16_bits(65504.0), 0x7bff); // f16 max
        assert_eq!(f32_to_f16_bits(65520.0), 0x7c00); // rounds to +Inf
        assert_eq!(f32_to_f16_bits(1.0e9), 0x7c00); // overflow -> +Inf
        assert_eq!(f32_to_f16_bits(f32::INFINITY), 0x7c00);
        assert_eq!(f32_to_f16_bits(f32::NEG_INFINITY), 0xfc00);
        assert!(f16_bits_to_f32(f32_to_f16_bits(f32::NAN)).is_nan());
        // smallest f16 subnormal: 2^-24
        assert_eq!(f32_to_f16_bits(2.0f32.powi(-24)), 0x0001);
        assert_eq!(f16_bits_to_f32(0x0001).to_bits(), 2.0f32.powi(-24).to_bits());
        // half of it ties to even -> zero
        assert_eq!(f32_to_f16_bits(2.0f32.powi(-25)), 0x0000);
        // just above the tie rounds up
        assert_eq!(f32_to_f16_bits(2.0f32.powi(-25) * 1.5), 0x0001);
    }

    #[test]
    fn f16_round_to_nearest_even_tie() {
        // 1 + 2^-11 sits exactly between 1.0 (0x3c00) and the next f16
        // (0x3c01); RNE keeps the even code.
        let tie = 1.0f32 + 2.0f32.powi(-11);
        assert_eq!(f32_to_f16_bits(tie), 0x3c00);
        // nudging the sticky bits up breaks the tie upward
        let above = 1.0f32 + 2.0f32.powi(-11) + 2.0f32.powi(-24);
        assert_eq!(f32_to_f16_bits(above), 0x3c01);
        // 1 + 3 * 2^-11 ties between 0x3c01 and 0x3c02 -> even 0x3c02
        let tie_odd = 1.0f32 + 3.0 * 2.0f32.powi(-11);
        assert_eq!(f32_to_f16_bits(tie_odd), 0x3c02);
    }

    #[test]
    fn f16_decode_of_every_finite_code_reencodes_exactly() {
        // decode is exact, so encode(decode(h)) == h for all non-NaN codes
        for h in 0..=0xffffu16 {
            if (h >> 10) & 0x1f == 0x1f && h & 0x3ff != 0 {
                continue; // NaN payloads canonicalize; skip
            }
            let x = f16_bits_to_f32(h);
            assert_eq!(f32_to_f16_bits(x), h, "code {h:#06x}");
        }
    }

    #[test]
    fn int8_block_scale_is_power_of_two_and_covers() {
        for max_abs in [1.0f32, 0.5, 127.0, 128.0, 1.0e-8, 3.7e5, 1.0e38] {
            let s = block_scale(max_abs);
            // power of two: mantissa bits are zero
            assert_eq!(s.to_bits() & 0x007f_ffff, 0, "scale {s} for {max_abs}");
            assert!(127.0 * s >= max_abs, "scale {s} too small for {max_abs}");
            if s > MIN_SCALE {
                assert!(127.0 * (s * 0.5) < max_abs, "scale {s} not minimal");
            }
        }
        assert_eq!(block_scale(0.0), 1.0);
        assert_eq!(block_scale(f32::NAN), 1.0);
    }

    #[test]
    fn int8_uniform_block_roundtrips_exactly() {
        // all-1.0 block: scale 2^-6 (127 * 2^-6 = 1.984... >= 1), code 64
        let xs = vec![1.0f32; QBLOCK];
        let store = ParamStore::from_f32(ParamStoreMode::Int8, &xs);
        let mut out = vec![0.0f32; QBLOCK];
        store.dequant_into(&mut out);
        for o in &out {
            assert_eq!(o.to_bits(), 1.0f32.to_bits());
        }
    }

    #[test]
    fn quantize_dequant_requant_is_idempotent() {
        let mut rng = Rng::new(0x51_70_53);
        for mode in [ParamStoreMode::F16, ParamStoreMode::Int8] {
            for n in [1usize, 63, 64, 65, 1000] {
                let mut xs = vec![0.0f32; n];
                rng.fill_normal(&mut xs);
                let mut store = ParamStore::from_f32(mode, &xs);
                let mut once = vec![0.0f32; n];
                store.dequant_into(&mut once);
                store.store_from(&once);
                let mut twice = vec![0.0f32; n];
                store.dequant_into(&mut twice);
                for (a, b) in once.iter().zip(twice.iter()) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{mode:?} n={n}");
                }
            }
        }
    }

    #[test]
    fn perturb_matches_materialized_dequant_bitwise() {
        let mut rng = Rng::new(42);
        for mode in [ParamStoreMode::F32, ParamStoreMode::F16, ParamStoreMode::Int8] {
            for n in [1usize, 255, 256, 257, 1337] {
                let mut xs = vec![0.0f32; n];
                let mut v = vec![0.0f32; n];
                rng.fill_normal(&mut xs);
                rng.fill_normal(&mut v);
                let store = ParamStore::from_f32(mode, &xs);
                let tau = 0.01f32;
                let mut fused = vec![0.0f32; n];
                store.perturb_into(tau, &v, &mut fused);
                let mut dq = vec![0.0f32; n];
                store.dequant_into(&mut dq);
                let mut reference = vec![0.0f32; n];
                lanes::fma_axpy_into(&mut reference, &dq, tau, &v);
                for (a, b) in fused.iter().zip(reference.iter()) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{mode:?} n={n}");
                }
            }
        }
    }

    #[test]
    fn resident_bytes_per_mode() {
        // the tracker is global and tests run in parallel, so we only pin
        // the per-store byte math here (registration is exercised by the
        // memory-table bench)
        let xs = vec![1.0f32; 128];
        let f32s = ParamStore::from_f32(ParamStoreMode::F32, &xs);
        assert_eq!(f32s.resident_bytes(), 128 * 4);
        let f16s = ParamStore::from_f32(ParamStoreMode::F16, &xs);
        assert_eq!(f16s.resident_bytes(), 128 * 2);
        let i8s = ParamStore::from_f32(ParamStoreMode::Int8, &xs);
        assert_eq!(i8s.resident_bytes(), 128 + 2 * 4);
    }

    #[test]
    fn convert_changes_mode_preserving_grid_values() {
        let xs: Vec<f32> = (0..100).map(|i| i as f32 * 0.25).collect();
        // values on the f16 grid survive f32 -> f16 -> f32 exactly
        let f16s = ParamStore::from_f32(ParamStoreMode::F16, &xs);
        let back = f16s.convert(ParamStoreMode::F32);
        assert_eq!(back.mode(), ParamStoreMode::F32);
        for (a, b) in back.as_f32().iter().zip(xs.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn parse_and_label_roundtrip() {
        for mode in [ParamStoreMode::F32, ParamStoreMode::F16, ParamStoreMode::Int8] {
            assert_eq!(ParamStoreMode::parse(mode.label()), Some(mode));
        }
        assert_eq!(ParamStoreMode::parse("f64"), None);
    }
}
