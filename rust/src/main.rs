//! zo-ldsd: the L3 coordinator CLI (also installed as `zo`).
//!
//! Subcommand surface (each supports `--help`):
//!   info                      inspect artifacts/manifest + runtime
//!   train                     one fine-tuning run (model x mode x method)
//!   grid                      emit / run wire-format trial grids
//!   serve                     coordinator: farm a grid to workers (§17)
//!   work                      worker: poll a coordinator for leases
//!   toy                       Fig. 2 toy experiment (DGD on a9a-like data)
//!   landscape                 Fig. 1 alignment landscape grid
//!   memory                    ZO-vs-FO memory table
//!   store                     content-addressed store maintenance
//!                             (gc | verify | ls; DESIGN.md §16)
//!   bench-gate                the CI benchmark-regression gate
//!
//! Benches regenerate the paper's tables/figures: `cargo bench`.

use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use zo_ldsd::cli::{Args, CommandSpec};
use zo_ldsd::config::{Manifest, TrainMode};
use zo_ldsd::coordinator::wire;
use zo_ldsd::coordinator::{
    deterministic_report, run_grid, run_local_trial, run_trial, table1_grid, MlpTrial,
    OracleSpec, TransformerTrial, TrialResult, TrialSpec,
};
use zo_ldsd::data::{CorpusSpec, SyntheticRegression};
use zo_ldsd::exec::ExecContext;
use zo_ldsd::metrics::MemoryReport;
use zo_ldsd::model::{Activation, LoraTargets, MlpSpec, Pool};
use zo_ldsd::optim::{DgdConfig, DgdRunner};
use zo_ldsd::oracle::{LinRegOracle, Oracle};
use zo_ldsd::report::Table;
use zo_ldsd::runtime::Runtime;
use zo_ldsd::sampler::expected_alignment_mc;
use zo_ldsd::service::{Coordinator, CoordinatorConfig, WorkerConfig};
use zo_ldsd::train::TrainConfig;

/// Every subcommand's declared surface: usage, options, flags.  The
/// global options `--threads` and `--store-dir` are shared by listing
/// them in each accepting command.
const COMMANDS: &[CommandSpec] = &[
    CommandSpec {
        name: "info",
        summary: "show manifest + runtime status",
        usage: "  info [--artifacts DIR]",
        opts: &["artifacts"],
        flags: &[],
    },
    CommandSpec {
        name: "train",
        summary: "one fine-tuning run (model x mode x method)",
        usage: "\
  train --model M --mode ft|lora --method 2fwd|6fwd|alg2
        [--oracle pjrt|mlp|transformer] [--config FILE] [--set K=V]...
        [--hidden 64,64] [--activation tanh|relu] [--in-dim N]
        [--layers N] [--heads N] [--d-model N] [--d-ff N]
        [--lora-rank N] [--lora-targets qv|qkvo|...]
        [--pool cls|last] [--causal 0|1] [--train-examples N]
        [--optimizer zo_sgd|zo_adamm|jaguar] [--lr F] [--budget N]
        [--eval-every N] [--eval-batches N] [--seed N] [--artifacts DIR]
        [--probe-dispatch batched|per-probe] [--threads N]
        [--probe-storage auto|materialized|streamed]
        [--param-store f32|f16|int8] [--gemm reference|blocked]
        [--checkpoint-dir DIR] [--checkpoint-every N] [--resume]
        [--store-dir DIR] [--max-run-steps N]

`--oracle mlp` trains the forward-only MLP classifier on the synthetic
corpus — no artifacts needed; epoch-shuffled minibatches by default
(--train-examples 4096, 0 = sequential).
`--oracle transformer` trains the host-side decoder transformer on the
same corpus — also artifact-free; --mode lora restricts the trainable
subspace to the LoRA adapters + head.
Snapshots and completed-trial records live in a content-addressed store
(default <checkpoint-dir>/store; --store-dir beats ZO_STORE_DIR beats
the default — DESIGN.md §17).",
        opts: &[
            "model", "mode", "method", "oracle", "config", "set", "hidden", "activation",
            "in-dim", "layers", "heads", "d-model", "d-ff", "lora-rank", "lora-targets",
            "pool", "causal", "train-examples", "optimizer", "lr", "budget", "eval-every",
            "eval-batches", "seed", "artifacts", "probe-dispatch", "threads",
            "probe-storage", "param-store", "gemm", "checkpoint-dir", "checkpoint-every",
            "store-dir", "max-run-steps",
        ],
        flags: &["resume"],
    },
    CommandSpec {
        name: "grid",
        summary: "emit / run wire-format trial grids",
        usage: "\
  grid emit --preset table1-smoke|table1|table1-full [--budget N]
            [--out FILE]
  grid run  --specs FILE [--checkpoint-dir DIR] [--threads N]
            [--artifacts DIR] [--report FILE] [--expect-cached]

`emit` writes a schema-versioned wire grid file (the exact JSON the
service protocol ships); `run` executes one in-process through
run_grid.  --checkpoint-dir turns on per-trial checkpoint + resume with
the grid's warm-start cache; --report writes the deterministic
canonical report (byte-comparable across runs and against `serve`);
--expect-cached asserts every trial was served from the cache with
zero training-session oracle calls.",
        opts: &["preset", "budget", "out", "specs", "checkpoint-dir", "threads",
                "artifacts", "report"],
        flags: &["expect-cached"],
    },
    CommandSpec {
        name: "serve",
        summary: "coordinator: farm a grid to workers over HTTP/JSON",
        usage: "\
  serve --dir DIR [--addr HOST:PORT] [--addr-file FILE] [--specs FILE]
        [--lease-timeout-ms N] [--poll-ms N] [--until-done]
        [--report FILE] [--expect-cached]

Binds the coordinator (default 127.0.0.1:0; --addr-file records the
bound address for scripts), resumes any queue.json persisted by a
previous coordinator in --dir, and enqueues --specs (idempotent by
canonical spec hash; trials already pinned in grid.lock.json are served
from the store with zero training steps).  Leases expire after
--lease-timeout-ms (default 60000) and requeue.  --until-done blocks
until every trial is terminal, writes the deterministic report, and
shuts down gracefully (persisting the queue); without it the
coordinator serves until killed.",
        opts: &["dir", "addr", "addr-file", "specs", "lease-timeout-ms", "poll-ms",
                "report"],
        flags: &["until-done", "expect-cached"],
    },
    CommandSpec {
        name: "work",
        summary: "worker: poll a coordinator for leased trials",
        usage: "\
  work --connect HOST:PORT --dir DIR [--threads N] [--poll-ms N]
       [--retries N] [--backoff-ms N] [--max-leases N]

Polls the coordinator for leases, runs trials through the local grid
path (checkpoint + resume in --dir, blobs in --dir/store), pushes each
outcome record and its curve blobs into the coordinator's store, and
submits the result.  RPCs retry --retries times with exponential
backoff from --backoff-ms.  Exits when the coordinator reports the
queue done (or after --max-leases leases).",
        opts: &["connect", "dir", "threads", "poll-ms", "retries", "backoff-ms",
                "max-leases"],
        flags: &[],
    },
    CommandSpec {
        name: "toy",
        summary: "Fig. 2 toy experiment (DGD on a9a-like data)",
        usage: "  toy [--steps N] [--variant baseline|ldsd] [--seed N]",
        opts: &["steps", "variant", "seed"],
        flags: &[],
    },
    CommandSpec {
        name: "landscape",
        summary: "Fig. 1 alignment landscape grid",
        usage: "  landscape [--grid N] [--eps F]",
        opts: &["grid", "eps"],
        flags: &[],
    },
    CommandSpec {
        name: "memory",
        summary: "ZO-vs-FO memory table",
        usage: "  memory [--model M] [--artifacts DIR]",
        opts: &["model", "artifacts"],
        flags: &[],
    },
    CommandSpec {
        name: "store",
        summary: "content-addressed store maintenance (DESIGN.md §16)",
        usage: "\
  store gc|verify|ls [--store-dir DIR] [--checkpoint-dir DIR]
        [--root DIR]...

The store root resolves --store-dir, then ZO_STORE_DIR (nonempty),
then <--checkpoint-dir>/store — the uniform CONFIGURED > ENV
precedence (DESIGN.md §17).  `verify` re-hashes every object, `gc`
mark-and-sweeps unreachable ones (roots: the store's parent tree plus
any --root), `ls` lists objects.",
        opts: &["store-dir", "checkpoint-dir", "root"],
        flags: &[],
    },
    CommandSpec {
        name: "bench-gate",
        summary: "the CI benchmark-regression gate",
        usage: "\
  bench-gate --baseline FILE --current FILE
             [--threshold 0.20] [--bytes-threshold 0.20]
             [--gate loss_k,axpy_k,...] [--ab-max-ratio 0.67]
             [--ab-prefix lanes/] [--ab-specs P:slow:fast:R[,...]]
             [--store-dir DIR] [--store-label L]

Also installed as the standalone `bench-gate` binary; both run the
same driver (see DESIGN.md §12).",
        opts: &["baseline", "current", "threshold", "bytes-threshold", "gate",
                "ab-max-ratio", "ab-prefix", "ab-specs", "store-dir", "store-label"],
        flags: &[],
    },
];

fn global_usage() -> String {
    let mut out = String::from("zo <command> [options]   (each command supports --help)\n\ncommands:\n");
    for c in COMMANDS {
        out.push_str(&format!("  {:<12} {}\n", c.name, c.summary));
    }
    out.push_str("\nBenches regenerate the paper's tables/figures: `cargo bench`.\n");
    out
}

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn command(name: &str) -> &'static CommandSpec {
    COMMANDS
        .iter()
        .find(|c| c.name == name)
        .expect("dispatch table covers every parsed subcommand")
}

fn run() -> Result<()> {
    let names: Vec<&str> = COMMANDS.iter().map(|c| c.name).collect();
    let args = Args::from_env_with_flags(
        &names,
        &["resume", "help", "until-done", "expect-cached"],
    )?;
    let Some(name) = args.subcommand.as_deref() else {
        print!("{}", global_usage());
        return Ok(());
    };
    let spec = command(name);
    if args.flag("help") {
        println!("{}", spec.help());
        return Ok(());
    }
    spec.validate(&args)?;
    match name {
        "info" => cmd_info(&args),
        "train" => cmd_train(&args),
        "grid" => cmd_grid(&args),
        "serve" => cmd_serve(&args),
        "work" => cmd_work(&args),
        "toy" => cmd_toy(&args),
        "landscape" => cmd_landscape(&args),
        "memory" => cmd_memory(&args),
        "store" => cmd_store(&args),
        "bench-gate" => zo_ldsd::bench::regression::gate_cli(&args),
        _ => unreachable!("dispatch table covers every parsed subcommand"),
    }
}

fn artifacts_dir(args: &Args) -> String {
    args.get_or("artifacts", "artifacts").to_string()
}

fn cmd_info(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let rt = Runtime::new(&dir)?;
    println!("platform: {}", rt.platform());
    let manifest = Manifest::load(&dir)?;
    let mut t = Table::new(
        "models",
        &["model", "d_ft", "d_lora", "batch", "seq", "K", "pretrain acc"],
    );
    for (name, m) in &manifest.models {
        t.row(vec![
            name.clone(),
            m.d_ft.to_string(),
            m.d_lora.to_string(),
            m.shapes.batch.to_string(),
            m.shapes.seq.to_string(),
            m.shapes.k.to_string(),
            m.pretrain_accuracy
                .map(|a| format!("{a:.3}"))
                .unwrap_or_else(|| "-".into()),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    // Layered config: file (--config) < CLI options < --set overrides.
    let mut kv = match args.get("config") {
        Some(path) => zo_ldsd::config::KvConfig::load(std::path::Path::new(path))?,
        None => zo_ldsd::config::KvConfig::default(),
    };
    for (key, cli) in [
        ("model", "model"), ("mode", "mode"), ("method", "method"),
        ("optimizer.name", "optimizer"), ("optimizer.lr", "lr"),
        ("budget", "budget"), ("eval_every", "eval-every"), ("seed", "seed"),
        ("probe_dispatch", "probe-dispatch"), ("threads", "threads"),
        ("probe_storage", "probe-storage"),
        ("param_store", "param-store"),
        ("gemm", "gemm"),
        ("checkpoint.dir", "checkpoint-dir"),
        ("checkpoint.every", "checkpoint-every"),
        ("checkpoint.max_run_steps", "max-run-steps"),
        ("store.dir", "store-dir"),
        ("oracle", "oracle"),
        ("mlp.hidden", "hidden"),
        ("mlp.activation", "activation"),
        ("mlp.in_dim", "in-dim"),
        ("transformer.layers", "layers"),
        ("transformer.heads", "heads"),
        ("transformer.d_model", "d-model"),
        ("transformer.d_ff", "d-ff"),
        ("transformer.lora_rank", "lora-rank"),
        ("transformer.lora_targets", "lora-targets"),
        ("transformer.pool", "pool"),
        ("transformer.causal", "causal"),
        ("shuffle.n_train", "train-examples"),
    ] {
        if let Some(v) = args.get(cli) {
            kv.set(key, v);
        }
    }
    for ov in args.get_all("set") {
        kv.apply_override(ov)?;
    }

    let dir = artifacts_dir(args);
    let oracle_kind = kv.get_or("oracle", "pjrt").to_string();
    let model = kv.get_or("model", "roberta_mini").to_string();
    let mode = TrainMode::parse(kv.get_or("mode", "lora"))?;
    let method = kv.get_or("method", "alg2").to_string();
    let optimizer = kv.get_or("optimizer.name", "zo_sgd").to_string();
    let lr = kv.get_f64_or("optimizer.lr", 1e-4)? as f32;
    let budget = kv.get_u64_or("budget", 6000)?;
    let eval_every = kv.get_u64_or("eval_every", 1200)?;
    let seed = kv.get_u64_or("seed", 0)?;

    let mut cfg = match method.as_str() {
        "2fwd" => TrainConfig::gaussian_2fwd(&optimizer, lr, budget),
        "6fwd" => TrainConfig::gaussian_6fwd(&optimizer, lr, budget),
        "alg2" => TrainConfig::algorithm2(&optimizer, lr, budget),
        other => bail!("unknown method '{other}' (2fwd|6fwd|alg2)"),
    };
    cfg.eval_every = eval_every;
    cfg.seed = seed;
    // Crash-safe checkpoint/resume (DESIGN.md §11): snapshots land under
    // <checkpoint-dir>/<sanitized trial id>/; --resume picks up the newest
    // valid one and continues bitwise-identically.  --max-run-steps is the
    // cooperative-preemption point for elastic workers.
    cfg.checkpoint = zo_ldsd::train::CheckpointConfig {
        dir: kv.get("checkpoint.dir").map(String::from),
        every: kv.get_u64_or("checkpoint.every", 0)?,
        resume: args.flag("resume") || kv.get_bool_or("checkpoint.resume", false)?,
        max_run_steps: kv.get_u64_or("checkpoint.max_run_steps", 0)?,
        // blob store location; None = <checkpoint-dir>/store unless
        // ZO_STORE_DIR forces the unconfigured default (DESIGN.md §17)
        store_dir: kv.get("store.dir").map(String::from),
    };
    if cfg.checkpoint.every > 0 && cfg.checkpoint.dir.is_none() {
        bail!("--checkpoint-every needs --checkpoint-dir");
    }
    if cfg.checkpoint.resume && cfg.checkpoint.dir.is_none() {
        bail!("--resume needs --checkpoint-dir");
    }
    if cfg.checkpoint.max_run_steps > 0 && cfg.checkpoint.dir.is_none() {
        // without a directory the halt snapshot has nowhere to go and the
        // preempted progress would be unrecoverable
        bail!("--max-run-steps needs --checkpoint-dir (the halt snapshot must land somewhere)");
    }
    // Minibatch ordering: the MLP workload epoch-shuffles a finite prefix
    // by default; --train-examples 0 keeps the sequential stream (the
    // PJRT default).  The batch cursor rides in snapshots, so shuffled
    // runs resume bitwise-identically (DESIGN.md §12).
    let n_train_default =
        if matches!(oracle_kind.as_str(), "mlp" | "transformer") { 4096 } else { 0 };
    let n_train = kv.get_u64_or("shuffle.n_train", n_train_default)?;
    if n_train > 0 {
        cfg.shuffle = Some(zo_ldsd::train::ShuffleSpec { n_train });
    }
    let dispatch =
        zo_ldsd::train::ProbeDispatch::parse(kv.get_or("probe_dispatch", "batched"))?;
    // materialized K x d matrix, streamed seed replay, or auto-selection
    // by memory budget; bitwise-identical trajectories (DESIGN.md §10)
    let storage =
        zo_ldsd::train::ProbeStorage::parse(kv.get_or("probe_storage", "auto"))?;
    // resident parameter storage: f32, or a quantized (f16/int8) store
    // evaluated through fused dequant kernels (DESIGN.md §14)
    let param_store = {
        let s = kv.get_or("param_store", "f32");
        match zo_ldsd::train::ParamStoreMode::parse(s) {
            Some(m) => m,
            None => bail!("unknown param store '{s}' (f32|f16|int8)"),
        }
    };
    // GEMM engine: the cache-blocked batched kernel (default) or the
    // row-at-a-time reference loop; identical bits either way
    // (DESIGN.md §15)
    let gemm = {
        let s = kv.get_or("gemm", "blocked");
        match zo_ldsd::train::GemmMode::parse(s) {
            Some(m) => m,
            None => bail!("unknown gemm engine '{s}' (reference|blocked)"),
        }
    };
    // --threads 0 (the default) means "size from the environment":
    // ZO_THREADS if set, else cores - 1 — the shared CONFIGURED > ENV
    // resolution (DESIGN.md §17).  Results are bitwise identical for any
    // thread count (DESIGN.md §9).
    let threads = kv.get_u64_or("threads", 0)? as usize;
    let exec = ExecContext::resolve(threads);

    let eval_batches = args.get_usize("eval-batches", 8)?;
    let (id, oracle) = match oracle_kind.as_str() {
        // forward-only MLP over the synthetic corpus: no artifacts or
        // runtime needed (DESIGN.md §12)
        "mlp" => {
            let hidden = MlpSpec::parse_hidden(kv.get_or("mlp.hidden", "64,64"))?;
            let activation = Activation::parse(kv.get_or("mlp.activation", "tanh"))?;
            let in_dim = kv.get_u64_or("mlp.in_dim", 128)? as usize;
            let widths: Vec<String> = hidden.iter().map(|h| h.to_string()).collect();
            let id = format!(
                "mlp{}-{}/{method}/{optimizer}",
                widths.join("x"),
                activation.label()
            );
            let trial = MlpTrial {
                hidden,
                activation,
                in_dim,
                corpus: CorpusSpec::default_mini(),
                init_seed: seed,
                eval_batch: 32,
            };
            (id, OracleSpec::Mlp(trial))
        }
        // host-side transformer + LoRA over the same corpus: the paper's
        // workload shape without artifacts (DESIGN.md §13)
        "transformer" => {
            let layers = kv.get_u64_or("transformer.layers", 4)? as usize;
            let heads = kv.get_u64_or("transformer.heads", 4)? as usize;
            let d_model = kv.get_u64_or("transformer.d_model", 128)? as usize;
            let d_ff = kv.get_u64_or("transformer.d_ff", 4 * d_model as u64)? as usize;
            let lora_rank = kv.get_u64_or("transformer.lora_rank", 8)? as usize;
            let lora_targets =
                LoraTargets::parse(kv.get_or("transformer.lora_targets", "qv"))?;
            let pool = Pool::parse(kv.get_or("transformer.pool", "cls"))?;
            let causal = kv.get_bool_or("transformer.causal", false)?;
            let trial = TransformerTrial {
                layers,
                heads,
                d_model,
                d_ff,
                lora_rank,
                lora_targets,
                causal,
                pool,
                corpus: CorpusSpec::default_mini(),
                init_seed: seed,
                eval_batch: 32,
            };
            // validate the architecture up front so flag errors surface
            // before any training state is built
            let tspec = trial.model_spec()?;
            let id =
                format!("{}/{}/{method}/{optimizer}", tspec.label(), mode.as_str());
            (id, OracleSpec::Transformer(trial))
        }
        "pjrt" => (
            format!("{model}/{}/{method}/{optimizer}", mode.as_str()),
            OracleSpec::Pjrt,
        ),
        other => bail!("unknown oracle '{other}' (pjrt|mlp|transformer)"),
    };
    let spec = TrialSpec {
        id,
        model,
        mode,
        config: cfg,
        eval_batches,
        probe_dispatch: Some(dispatch),
        probe_storage: Some(storage),
        param_store: Some(param_store),
        gemm: Some(gemm),
        checkpoint: None, // the config's policy applies
        oracle,
    };
    println!(
        "running {} (budget {budget} forwards, {} threads, {} probes requested)",
        spec.id,
        exec.threads(),
        storage.label(),
    );
    let result = match &spec.oracle {
        OracleSpec::Pjrt => {
            let manifest = Manifest::load(&dir)?;
            let rt = Runtime::new(&dir)?;
            run_trial(&dir, &manifest, &spec, &rt, &exec)?
        }
        OracleSpec::Mlp(_) | OracleSpec::Transformer(_) => {
            run_local_trial(&dir, &spec, &exec)?
        }
    };
    let o = &result.outcome;
    for (calls, acc) in &o.acc_curve {
        println!("  calls {calls:>8}  accuracy {acc:.4}");
    }
    // probe storage reported from the result: what the run *resolved to*
    // after the env override and capability fallbacks, not the request
    println!(
        "done: steps {} calls {} final acc {:.4} best {:.4} ({:.1}s, {} probes, peak {:.1} MiB)",
        o.steps,
        o.oracle_calls,
        o.final_accuracy,
        o.best_accuracy,
        o.wall_seconds,
        result.probe_storage,
        result.probe_peak_bytes as f64 / (1 << 20) as f64,
    );
    if !o.completed {
        // cmd_train rejects --max-run-steps without --checkpoint-dir, so a
        // halted session always has a snapshot on disk to resume from
        println!(
            "session halted at --max-run-steps; rerun with --resume to continue \
             (bitwise-identical to an uninterrupted run)"
        );
    }
    Ok(())
}

/// Load a wire grid file (as written by `grid emit` or persisted by the
/// coordinator) into specs.
fn load_specs(path: &str) -> Result<Vec<TrialSpec>> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow!("reading grid file {path}: {e}"))?;
    let j = zo_ldsd::jsonio::parse(&text).map_err(|e| anyhow!("parsing {path}: {e}"))?;
    wire::grid_from_json(&j)
}

/// Summarize grid results, write the deterministic report when asked,
/// and enforce `--expect-cached`.  Shared by `grid run` and `serve`.
fn finish_grid(
    results: &[Result<TrialResult>],
    report_path: Option<&str>,
    expect_cached: bool,
) -> Result<()> {
    let mut failures = 0usize;
    let mut cache_misses: Vec<String> = Vec::new();
    for r in results {
        match r {
            Ok(tr) => {
                println!(
                    "  {}  acc {:.4}  steps {}  calls {}{}",
                    tr.spec_id,
                    tr.outcome.final_accuracy,
                    tr.outcome.steps,
                    tr.outcome.oracle_calls,
                    if tr.cached { "  (cached)" } else { "" },
                );
                if expect_cached && !(tr.cached && tr.session_oracle_calls == 0) {
                    cache_misses.push(format!(
                        "{} (cached {}, session oracle calls {})",
                        tr.spec_id, tr.cached, tr.session_oracle_calls
                    ));
                }
            }
            Err(e) => {
                failures += 1;
                eprintln!("  trial failed: {e:#}");
            }
        }
    }
    if let Some(path) = report_path {
        std::fs::write(path, deterministic_report(results))
            .map_err(|e| anyhow!("writing report {path}: {e}"))?;
        println!("wrote deterministic report to {path}");
    }
    if expect_cached && !cache_misses.is_empty() {
        bail!(
            "--expect-cached but {} trial(s) ran cold: {}",
            cache_misses.len(),
            cache_misses.join("; ")
        );
    }
    if failures > 0 {
        bail!("{failures} trial(s) failed");
    }
    Ok(())
}

fn cmd_grid(args: &Args) -> Result<()> {
    match args.positional.first().map(String::as_str) {
        Some("emit") => {
            let preset = args.get_or("preset", "table1-smoke");
            let (default_budget, full, smoke) = match preset {
                "table1-smoke" => (120, false, true),
                "table1" => (2400, false, false),
                "table1-full" => (2400, true, false),
                other => bail!("unknown preset '{other}' (table1-smoke|table1|table1-full)"),
            };
            let budget = args.get_u64("budget", default_budget)?;
            let specs = table1_grid(budget, full, smoke);
            let text = format!(
                "{}\n",
                zo_ldsd::jsonio::to_string_canonical(&wire::grid_to_json(&specs))
            );
            match args.get("out") {
                Some(path) => {
                    std::fs::write(path, text)
                        .map_err(|e| anyhow!("writing grid file {path}: {e}"))?;
                    println!("wrote {} trial spec(s) to {path}", specs.len());
                }
                None => print!("{text}"),
            }
            Ok(())
        }
        Some("run") => {
            let mut specs = load_specs(args.require("specs")?)?;
            if let Some(d) = args.get("checkpoint-dir") {
                for s in &mut specs {
                    s.checkpoint = Some(zo_ldsd::snapshot::CheckpointConfig {
                        dir: Some(d.to_string()),
                        every: 0,
                        resume: true,
                        max_run_steps: 0,
                        store_dir: None,
                    });
                }
            }
            let exec = ExecContext::resolve(args.get_usize("threads", 0)?);
            println!(
                "grid run: {} trial(s) on {} thread(s)",
                specs.len(),
                exec.threads()
            );
            let results = run_grid(&artifacts_dir(args), specs, &exec);
            finish_grid(&results, args.get("report"), args.flag("expect-cached"))
        }
        Some(other) => bail!("unknown grid action '{other}' (emit|run)"),
        None => bail!("grid needs an action (emit|run); see `grid --help`"),
    }
}

fn cmd_serve(args: &Args) -> Result<()> {
    let dir = args.require("dir")?;
    let mut coordinator = Coordinator::bind(CoordinatorConfig {
        addr: args.get_or("addr", "127.0.0.1:0").to_string(),
        dir: dir.into(),
        lease_timeout: Duration::from_millis(args.get_u64("lease-timeout-ms", 60_000)?),
    })?;
    let addr = coordinator.addr();
    println!("coordinator listening on {addr}");
    if let Some(path) = args.get("specs") {
        let specs = load_specs(path)?;
        let total = specs.len();
        let cached = coordinator.enqueue(specs)?;
        println!("enqueued {total} trial(s), {cached} served from the warm-start cache");
    }
    // written only after --specs are queued: a worker gated on this file
    // can never observe the pre-enqueue (empty, trivially "done") queue
    if let Some(path) = args.get("addr-file") {
        std::fs::write(path, format!("{addr}\n"))
            .map_err(|e| anyhow!("writing addr file {path}: {e}"))?;
    }
    if !args.flag("until-done") {
        // serve until killed: the queue persists on graceful shutdown
        // requests (POST /api/v1/shutdown) and survives restarts
        loop {
            std::thread::sleep(Duration::from_secs(3600));
        }
    }
    let poll = Duration::from_millis(args.get_u64("poll-ms", 50)?);
    let results = coordinator.run_until_done(poll)?;
    let stats = coordinator.stats();
    println!(
        "queue drained: {} lease(s), {} requeue(s), {} outcome(s), {} duplicate(s), {} cached",
        stats.leases_granted,
        stats.requeues,
        stats.outcomes_accepted,
        stats.duplicates,
        stats.cached_on_enqueue,
    );
    coordinator.shutdown()?;
    finish_grid(&results, args.get("report"), args.flag("expect-cached"))
}

fn cmd_work(args: &Args) -> Result<()> {
    let max_leases = args.get_u64("max-leases", 0)?;
    let cfg = WorkerConfig {
        connect: args.require("connect")?.to_string(),
        dir: args.require("dir")?.into(),
        threads: args.get_usize("threads", 0)?,
        poll: Duration::from_millis(args.get_u64("poll-ms", 50)?),
        retries: args.get_u64("retries", 4)? as u32,
        backoff: Duration::from_millis(args.get_u64("backoff-ms", 100)?),
        max_leases: if max_leases == 0 { None } else { Some(max_leases) },
    };
    let report = zo_ldsd::service::run_worker(&cfg)?;
    println!(
        "worker done: {} trial(s), {} eval shard(s), {} error(s)",
        report.trials_run, report.evals_run, report.errors
    );
    Ok(())
}

fn cmd_toy(args: &Args) -> Result<()> {
    let steps = args.get_usize("steps", 400)?;
    let seed = args.get_u64("seed", 1)?;
    let variant = args.get_or("variant", "ldsd");
    let ds = SyntheticRegression::a9a_like(2048, 0xA9A);
    let d = ds.x.cols;
    let mut oracle = LinRegOracle::new(ds.x, ds.y, vec![0.0; d]);
    let cfg = match variant {
        "baseline" => {
            let mut c = DgdConfig::paper_baseline(steps, seed);
            c.gamma_x = 2.0; // rescaled for the synthetic conditioning
            c
        }
        "ldsd" => {
            let mut c = DgdConfig::paper_ldsd(steps, seed);
            c.gamma_x = 0.5;
            c.gamma_mu = 2e-4;
            c
        }
        other => bail!("unknown variant '{other}'"),
    };
    let mut runner = DgdRunner::new(cfg, oracle.dim());
    let trace = runner.run(&mut oracle)?;
    println!("step,cos(gx,grad),grad_norm,loss");
    let stride = (steps / 40).max(1);
    for i in (0..steps).step_by(stride) {
        println!(
            "{i},{:.4},{:.5},{:.6}",
            trace.alignment[i], trace.grad_norm[i], trace.loss[i]
        );
    }
    Ok(())
}

fn cmd_landscape(args: &Args) -> Result<()> {
    let grid = args.get_usize("grid", 41)?;
    let eps = args.get_f64("eps", 0.25)? as f32;
    // Fig. 1: d = 2, grad f = (1, 0)
    let gradient = [1.0f32, 0.0];
    println!("mu_x,mu_y,expected_alignment");
    for i in 0..grid {
        for j in 0..grid {
            let mx = -3.0 + 6.0 * i as f32 / (grid - 1) as f32;
            let my = -3.0 + 6.0 * j as f32 / (grid - 1) as f32;
            let c = expected_alignment_mc(&[mx, my], &gradient, eps, 4000, 99);
            println!("{mx:.3},{my:.3},{c:.5}");
        }
    }
    Ok(())
}

/// Resolve the store root for the `store` subcommand under the uniform
/// CONFIGURED > ENV precedence contract (DESIGN.md §17): an explicit
/// `--store-dir` wins, then `ZO_STORE_DIR` (nonempty), then
/// `<--checkpoint-dir>/store` — the same ordering
/// [`zo_ldsd::snapshot::resolve_store_dir`] applies on the training path.
fn store_root(args: &Args) -> Result<std::path::PathBuf> {
    if let Some(d) = args.get("store-dir") {
        return Ok(std::path::PathBuf::from(d));
    }
    if let Ok(env) = std::env::var("ZO_STORE_DIR") {
        if !env.trim().is_empty() {
            return Ok(std::path::PathBuf::from(env));
        }
    }
    if let Some(d) = args.get("checkpoint-dir") {
        return Ok(std::path::Path::new(d).join("store"));
    }
    bail!("store: need --store-dir, --checkpoint-dir or ZO_STORE_DIR");
}

fn cmd_store(args: &Args) -> Result<()> {
    let root = store_root(args)?;
    let store = zo_ldsd::store::Store::open(&root);
    match args.positional.first().map(String::as_str) {
        Some("ls") | None => {
            let objects = store.objects();
            for hash in &objects {
                let bytes = std::fs::metadata(store.object_path(hash))
                    .map(|m| m.len())
                    .unwrap_or(0);
                println!("{hash}  {bytes}");
            }
            println!("{} objects in {}", objects.len(), root.display());
        }
        Some("verify") => {
            let report = store.verify();
            println!(
                "verified {}: {} ok, {} corrupt",
                root.display(),
                report.ok,
                report.corrupt.len(),
            );
            for hash in &report.corrupt {
                eprintln!("corrupt: {hash}");
            }
            if !report.corrupt.is_empty() {
                bail!("store verify found {} corrupt object(s)", report.corrupt.len());
            }
        }
        Some("gc") => {
            // Roots: the tree holding the store (trial manifests and
            // grid.lock.json live next to a conventionally-placed store),
            // plus any explicitly passed --root trees.  The store root
            // itself (lockfiles) is always scanned.
            let mut roots: Vec<std::path::PathBuf> = Vec::new();
            if let Some(parent) = root.parent() {
                if !parent.as_os_str().is_empty() {
                    roots.push(parent.to_path_buf());
                }
            }
            roots.extend(args.get_all("root").into_iter().map(std::path::PathBuf::from));
            let report = store.gc(&roots)?;
            println!(
                "gc {}: {} live, {} swept ({} bytes reclaimed)",
                root.display(),
                report.live,
                report.swept,
                report.swept_bytes,
            );
        }
        Some(other) => bail!("unknown store action '{other}' (gc|verify|ls)"),
    }
    Ok(())
}

fn cmd_memory(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let manifest = Manifest::load(&dir)?;
    let model = args.get_or("model", "roberta_mini");
    let m = manifest.model(model)?;
    let report = MemoryReport::build(
        m.d_ft, m.d_ft, m.shapes.batch, m.shapes.seq, m.d_model,
        4 * m.d_model, 4, m.n_layers, m.shapes.k,
    );
    let mut t = Table::new(
        &format!("memory footprint: {model} (full fine-tuning)"),
        &["method", "total MiB", "x inference"],
    );
    for row in &report {
        t.row(vec![
            row.method.clone(),
            format!("{:.1}", row.total() as f64 / (1 << 20) as f64),
            format!("{:.2}", row.over_inference()),
        ]);
    }
    t.print();
    Ok(())
}
