//! MeZO-style seeded in-place perturbation (Malladi et al., 2023).
//!
//! The memory trick the paper's §1 cites: never materialize the direction
//! vector.  A step perturbs the parameters *in place* by streaming
//! N(0, 1) draws from a seeded generator, evaluates, replays the same
//! stream to flip the perturbation sign, evaluates again, and replays once
//! more to restore and apply the update — O(1) estimator state instead of
//! the O(d) direction buffer.
//!
//! Trade-off: the base-optimizer abstraction needs a dense gradient `g`,
//! so this estimator integrates as `ZoSgd`-only fused updates (like the
//! original MeZO, which fuses the SGD step into the replay).  It exists
//! (a) as the memory-table's "true O(1)" row and (b) to validate that our
//! dense-`g` pipeline loses nothing numerically (see tests).

use anyhow::Result;

use crate::oracle::Oracle;
use crate::rng::Rng;

/// Seeded in-place central-difference SGD with O(1) estimator state.
pub struct MezoSgd {
    /// Finite-difference scale.
    pub tau: f32,
    /// Learning rate used by [`MezoSgd::run`].
    pub lr: f32,
    /// momentumless by design: momentum would need an O(d) buffer and
    /// defeat the trick
    seed_counter: u64,
    base_seed: u64,
}

/// Diagnostics of one fused MeZO step.
#[derive(Clone, Debug)]
pub struct MezoStepInfo {
    /// f(x + tau z).
    pub loss_plus: f64,
    /// f(x - tau z).
    pub loss_minus: f64,
    /// The central-difference coefficient applied along z.
    pub fd_coeff: f64,
    /// Oracle calls spent (always 2).
    pub calls: u64,
}

impl MezoSgd {
    /// Build with finite-difference scale, learning rate and base seed.
    pub fn new(tau: f32, lr: f32, seed: u64) -> Self {
        Self { tau, lr, seed_counter: 0, base_seed: seed }
    }

    /// Estimator state: the seed counter only.
    pub fn state_bytes(&self) -> usize {
        16
    }

    fn perturb(oracle: &mut dyn Oracle, seed: u64, scale: f32) -> Result<()> {
        oracle.update_params(&mut |x| {
            let mut rng = Rng::new(seed);
            for v in x.iter_mut() {
                *v += scale * rng.normal() as f32;
            }
        })
    }

    /// One fused MeZO step: estimate along a seeded direction and apply
    /// the SGD update during the final replay.
    pub fn step(&mut self, oracle: &mut dyn Oracle, lr: f32) -> Result<MezoStepInfo> {
        let seed = self.base_seed ^ self.seed_counter.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        self.seed_counter += 1;
        let d = oracle.dim();
        let zero = vec![0.0f32; d];

        // x + tau z
        Self::perturb(oracle, seed, self.tau)?;
        let loss_plus = oracle.loss_dir(&zero, 0.0)?;
        // x - tau z  (replay: -2 tau)
        Self::perturb(oracle, seed, -2.0 * self.tau)?;
        let loss_minus = oracle.loss_dir(&zero, 0.0)?;
        let coeff = ((loss_plus - loss_minus) / (2.0 * self.tau as f64)) as f32;
        // restore (+tau) and apply update (-lr * coeff * z) in one replay
        Self::perturb(oracle, seed, self.tau - lr * coeff)?;
        Ok(MezoStepInfo {
            loss_plus,
            loss_minus,
            fd_coeff: coeff as f64,
            calls: 2,
        })
    }

    /// Convenience: run `steps` steps with the configured lr.
    pub fn run(&mut self, oracle: &mut dyn Oracle, steps: usize) -> Result<Vec<MezoStepInfo>> {
        (0..steps).map(|_| self.step(oracle, self.lr)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::oracle::QuadraticOracle;
    use crate::optim::{CentralK1Estimator, GradEstimator};
    use crate::sampler::GaussianSampler;
    use crate::tensor::axpy;

    #[test]
    fn mezo_descends_quadratic() {
        let d = 64;
        let mut oracle =
            QuadraticOracle::new(vec![1.0; d], vec![1.0; d], vec![0.0; d]);
        let mut mezo = MezoSgd::new(1e-3, 0.01, 7);
        let zero = vec![0.0f32; d];
        let f0 = oracle.loss_dir(&zero, 0.0).unwrap();
        mezo.run(&mut oracle, 400).unwrap();
        let f1 = oracle.loss_dir(&zero, 0.0).unwrap();
        assert!(f1 < 0.5 * f0, "mezo did not descend: {f0} -> {f1}");
    }

    /// The seeded replay must be numerically equivalent to the dense-g
    /// pipeline with the same direction: run one step of each from the
    /// same state and compare the loss trajectory statistically.
    #[test]
    fn mezo_matches_dense_pipeline_statistically() {
        let d = 32;
        let steps = 300;
        // dense pipeline
        let mut o1 = QuadraticOracle::new(vec![1.0; d], vec![1.0; d], vec![0.0; d]);
        let mut est = CentralK1Estimator::new(GaussianSampler::new(d, 5), 1e-3);
        let mut g = vec![0.0f32; d];
        for _ in 0..steps {
            est.estimate(&mut o1, &mut g).unwrap();
            o1.update_params(&mut |x| axpy(-0.01, &g, x)).unwrap();
        }
        let zero = vec![0.0f32; d];
        let f_dense = o1.loss_dir(&zero, 0.0).unwrap();
        // seeded in-place pipeline
        let mut o2 = QuadraticOracle::new(vec![1.0; d], vec![1.0; d], vec![0.0; d]);
        let mut mezo = MezoSgd::new(1e-3, 0.01, 5);
        mezo.run(&mut o2, steps).unwrap();
        let f_mezo = o2.loss_dir(&zero, 0.0).unwrap();
        // same algorithm, different direction streams: same convergence
        // level within a generous factor
        assert!(
            f_mezo < 4.0 * f_dense + 1e-3 && f_dense < 4.0 * f_mezo + 1e-3,
            "dense {f_dense} vs mezo {f_mezo}"
        );
    }

    #[test]
    fn mezo_state_is_constant() {
        let mezo = MezoSgd::new(1e-3, 0.01, 1);
        assert_eq!(mezo.state_bytes(), 16);
    }

    #[test]
    fn replay_restores_params_when_lr_zero() {
        let d = 16;
        let mut oracle = QuadraticOracle::isotropic(vec![1.0; d]);
        let before = oracle.params().to_vec();
        let mut mezo = MezoSgd::new(1e-2, 0.0, 3);
        mezo.step(&mut oracle, 0.0).unwrap();
        let after = oracle.params();
        for (a, b) in before.iter().zip(after.iter()) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }
}
