//! Gradient estimators: forward evaluations -> gradient surrogate.
//!
//! All estimators write a dense `g` into a caller-provided buffer so the
//! base optimizers are strategy-agnostic (the paper's plug-in claim), and
//! report exactly how many oracle calls they spent (the §5.1 budget-fair
//! protocol charges estimators by calls, not iterations).
//!
//! # Two-phase batched estimation
//!
//! Estimation is split into a `propose`/`consume` flow around the K x d
//! probe matrix:
//!
//! 1. [`GradEstimator::propose`] fills the estimator's reusable row-major
//!    probe matrix from its [`DirectionSampler`] and returns it as a
//!    [`ProbeBatch`] (no oracle calls yet);
//! 2. the caller evaluates the whole batch — normally one fused
//!    [`Oracle::loss_k`] dispatch, or K separate `loss_dir` calls for
//!    per-probe A/B benchmarking (`ProbeDispatch` in [`crate::train`]);
//! 3. [`GradEstimator::consume`] combines the probe losses into `g` with
//!    the blocked [`probe_combine_ctx`] kernel (plus at most one follow-up
//!    point evaluation: the forward-difference base loss, or Algorithm 2's
//!    central-difference probe at `-tau` along the selected direction).
//!
//! [`GradEstimator::estimate`] is the one-call convenience that wires the
//! three steps together; [`GradEstimator::estimate_with`] is the hot-path
//! variant that reuses a caller-provided probe-loss buffer across steps.
//!
//! Every O(d) and O(K d) pass goes through the estimator's installed
//! [`ExecContext`], so combines run shard-parallel with results bitwise
//! identical for any worker count (DESIGN.md §9).  The per-step probe
//! losses are kept in a reusable buffer exposed via
//! [`GradEstimator::last_losses`] — nothing on the per-step path allocates
//! after warmup.

use anyhow::{bail, Result};

use crate::exec::ExecContext;
use crate::oracle::Oracle;
use crate::sampler::DirectionSampler;
use crate::tensor::probe_combine_ctx;

/// One batch of probe evaluations requested by [`GradEstimator::propose`]:
/// `k` rows of a row-major `k x d` direction matrix, each to be evaluated
/// at `f(x + tau * dir)`.
#[derive(Clone, Copy, Debug)]
pub struct ProbeBatch<'a> {
    /// Row-major `k x d` direction matrix (borrowed from the estimator's
    /// reusable buffer; valid until the next `propose`).
    pub dirs: &'a [f32],
    /// Number of probe rows.
    pub k: usize,
    /// Finite-difference scale each row is evaluated at.
    pub tau: f32,
}

/// Outcome of one estimation step.
///
/// The full per-step loss vector lives in the estimator's reusable buffer
/// ([`GradEstimator::last_losses`]); this struct carries only the scalars
/// so the per-step path stays allocation-free.
#[derive(Clone, Copy, Debug)]
pub struct Estimate {
    /// Oracle calls consumed by this step.
    pub calls: u64,
    /// Scalar training-loss proxy for this step: the selected probe's
    /// loss (Algorithm 2), the base loss (forward averaging), or the
    /// `+tau` probe (central difference).
    pub loss: f64,
    /// Index of the selected direction (Algorithm 2 line 4), if any.
    pub selected: Option<usize>,
    /// The finite-difference coefficient applied to the selected direction
    /// (0 when `g` is an average).
    pub fd_coeff: f64,
}

/// Turns forward evaluations into a dense gradient surrogate.
pub trait GradEstimator {
    /// Phase 1: sample this step's directions into the estimator's
    /// reusable probe matrix and describe the required evaluations.
    /// Performs no oracle calls.
    fn propose(&mut self) -> Result<ProbeBatch<'_>>;

    /// Phase 2: combine the `losses` of the last proposed batch (in row
    /// order) into `g` (len d).  May spend extra oracle calls for point
    /// evaluations that cannot be batched (see the module docs); the
    /// returned [`Estimate::calls`] covers the whole step including the
    /// batch itself.
    ///
    /// Each `consume` must be paired with a preceding call to
    /// [`GradEstimator::propose`]: combining without one (or twice for
    /// one propose) would silently read a stale or zero probe matrix,
    /// so it is an error.
    fn consume(
        &mut self,
        oracle: &mut dyn Oracle,
        losses: &[f64],
        g: &mut [f32],
    ) -> Result<Estimate>;

    /// Estimate grad f(x) into `g` (len d) in one call: propose, evaluate
    /// the batch via one fused [`Oracle::loss_k`] dispatch, consume.  The
    /// oracle's current batch must be set by the caller.
    fn estimate(&mut self, oracle: &mut dyn Oracle, g: &mut [f32]) -> Result<Estimate> {
        let mut scratch = Vec::new();
        self.estimate_with(oracle, g, &mut scratch)
    }

    /// [`GradEstimator::estimate`] with a caller-provided probe-loss
    /// buffer, reused across steps on the train-loop hot path (no per-step
    /// allocation).
    fn estimate_with(
        &mut self,
        oracle: &mut dyn Oracle,
        g: &mut [f32],
        probe_losses: &mut Vec<f64>,
    ) -> Result<Estimate> {
        {
            let batch = self.propose()?;
            oracle.loss_k_into(batch.dirs, batch.k, batch.tau, probe_losses)?;
        }
        self.consume(oracle, probe_losses, g)
    }

    /// Install the shard-parallel execution context used by the combine
    /// kernels, and forwarded to the estimator's direction sampler.
    fn set_exec(&mut self, _ctx: ExecContext) {}

    /// The probe losses of the last completed `consume` (diagnostics):
    /// batch losses in row order, followed by any extra point evaluations
    /// that step spent.  Borrowed from a buffer reused across steps.
    fn last_losses(&self) -> &[f64] {
        &[]
    }

    /// Oracle calls one step consumes (for budget planning).
    fn calls_per_step(&self) -> u64;

    /// Short identifier used in run labels.
    fn name(&self) -> &str;

    /// Bytes of persistent estimator state (memory accounting): direction
    /// buffers + sampler policy state.
    fn state_bytes(&self) -> usize;
}

/// Classical ZO central difference with a single probe direction
/// (MeZO-style; the "Gaussian, 2 forwards, more iterations" row of
/// Table 1):  g = v * (f(x + tau v) - f(x - tau v)) / (2 tau).
///
/// Batched form: the probe matrix is `[v; -v]` (2 x d), so both sides of
/// the central difference ride one `loss_k` dispatch.
pub struct CentralK1Estimator<S: DirectionSampler> {
    /// Direction source for the single probe v.
    pub sampler: S,
    /// Finite-difference scale.
    pub tau: f32,
    /// 2 x d probe matrix: row 0 is v, row 1 is -v.
    dirs: Vec<f32>,
    losses: Vec<f64>,
    exec: ExecContext,
    proposed: bool,
}

impl<S: DirectionSampler> CentralK1Estimator<S> {
    /// Build with a direction sampler and finite-difference scale.
    pub fn new(sampler: S, tau: f32) -> Self {
        let d = sampler.dim();
        Self {
            sampler,
            tau,
            dirs: vec![0.0; 2 * d],
            losses: Vec::with_capacity(2),
            exec: ExecContext::serial(),
            proposed: false,
        }
    }
}

impl<S: DirectionSampler> GradEstimator for CentralK1Estimator<S> {
    fn propose(&mut self) -> Result<ProbeBatch<'_>> {
        let d = self.sampler.dim();
        let (v, neg) = self.dirs.split_at_mut(d);
        self.sampler.sample(v, 1);
        let v_ro: &[f32] = v;
        self.exec.for_each_shard_mut(neg, |_, start, chunk| {
            for (i, n) in chunk.iter_mut().enumerate() {
                *n = -v_ro[start + i];
            }
        });
        self.proposed = true;
        Ok(ProbeBatch { dirs: &self.dirs, k: 2, tau: self.tau })
    }

    fn consume(
        &mut self,
        _oracle: &mut dyn Oracle,
        losses: &[f64],
        g: &mut [f32],
    ) -> Result<Estimate> {
        if !self.proposed {
            bail!("central_k1: consume without a matching propose");
        }
        if losses.len() != 2 {
            bail!("central_k1: expected 2 probe losses, got {}", losses.len());
        }
        self.proposed = false;
        let d = self.sampler.dim();
        let (fp, fm) = (losses[0], losses[1]);
        let coeff = (fp - fm) / (2.0 * self.tau as f64);
        let cf = coeff as f32;
        let v = &self.dirs[..d];
        self.exec.for_each_shard_mut(g, |_, start, gb| {
            for (i, gi) in gb.iter_mut().enumerate() {
                *gi = cf * v[start + i];
            }
        });
        self.losses.clear();
        self.losses.push(fp);
        self.losses.push(fm);
        Ok(Estimate { calls: 2, loss: fp, selected: Some(0), fd_coeff: coeff })
    }

    fn set_exec(&mut self, ctx: ExecContext) {
        self.sampler.set_exec(ctx.clone());
        self.exec = ctx;
    }

    fn last_losses(&self) -> &[f64] {
        &self.losses
    }

    fn calls_per_step(&self) -> u64 {
        2
    }

    fn name(&self) -> &str {
        "central_k1"
    }

    fn state_bytes(&self) -> usize {
        self.dirs.len() * 4 + self.sampler.state_bytes()
    }
}

/// Monte-Carlo forward-difference averaging (eq. 5 with one-point probes;
/// the "Gaussian, 6 forwards, same iterations" row):
/// g = (1/K) sum_i v_i (f(x + tau v_i) - f(x)) / tau.
///
/// Batched form: all K probes go through one `loss_k` dispatch; the base
/// loss f(x) is the one point evaluation `consume` performs, and the
/// combine is a single [`probe_combine_ctx`] reduce over the probe matrix.
pub struct ForwardAvgEstimator<S: DirectionSampler> {
    /// Direction source for the K probes.
    pub sampler: S,
    /// Finite-difference scale.
    pub tau: f32,
    /// Number of probe directions per step.
    pub k: usize,
    dirs: Vec<f32>,
    weights: Vec<f32>,
    losses: Vec<f64>,
    zero: Vec<f32>,
    exec: ExecContext,
    proposed: bool,
}

impl<S: DirectionSampler> ForwardAvgEstimator<S> {
    /// Build with a direction sampler, finite-difference scale and probe
    /// count (k >= 1).
    pub fn new(sampler: S, tau: f32, k: usize) -> Self {
        assert!(k >= 1);
        let d = sampler.dim();
        Self {
            sampler,
            tau,
            k,
            dirs: vec![0.0; k * d],
            weights: Vec::with_capacity(k),
            losses: Vec::with_capacity(k + 1),
            zero: vec![0.0; d],
            exec: ExecContext::serial(),
            proposed: false,
        }
    }
}

impl<S: DirectionSampler> GradEstimator for ForwardAvgEstimator<S> {
    fn propose(&mut self) -> Result<ProbeBatch<'_>> {
        self.sampler.sample(&mut self.dirs, self.k);
        self.proposed = true;
        Ok(ProbeBatch { dirs: &self.dirs, k: self.k, tau: self.tau })
    }

    fn consume(
        &mut self,
        oracle: &mut dyn Oracle,
        losses: &[f64],
        g: &mut [f32],
    ) -> Result<Estimate> {
        if !self.proposed {
            bail!("forward_avg: consume without a matching propose");
        }
        if losses.len() != self.k {
            bail!(
                "forward_avg: expected {} probe losses, got {}",
                self.k,
                losses.len()
            );
        }
        self.proposed = false;
        let d = self.sampler.dim();
        let f_base = oracle.loss_dir(&self.zero, 0.0)?;
        let denom = self.k as f64 * self.tau as f64;
        self.weights.clear();
        self.weights
            .extend(losses.iter().map(|l| ((l - f_base) / denom) as f32));
        probe_combine_ctx(&self.exec, &self.dirs, d, &self.weights, g);
        // trait contract: batch losses in row order first, then the extra
        // point evaluation (here the forward-difference base loss)
        self.losses.clear();
        self.losses.extend_from_slice(losses);
        self.losses.push(f_base);
        Ok(Estimate {
            calls: self.k as u64 + 1,
            loss: f_base,
            selected: None,
            fd_coeff: 0.0,
        })
    }

    fn set_exec(&mut self, ctx: ExecContext) {
        self.sampler.set_exec(ctx.clone());
        self.exec = ctx;
    }

    fn last_losses(&self) -> &[f64] {
        &self.losses
    }

    fn calls_per_step(&self) -> u64 {
        self.k as u64 + 1
    }

    fn name(&self) -> &str {
        "forward_avg"
    }

    fn state_bytes(&self) -> usize {
        (self.dirs.len() + self.weights.capacity() + self.zero.len()) * 4
            + self.sampler.state_bytes()
    }
}

/// Algorithm 2 (ZO-LDSD): sample K candidates from the (learnable) policy,
/// greedily select the probe with the lowest loss, take a central
/// difference along it, and update the policy from all K probe losses.
///
/// Works with *any* [`DirectionSampler`]; with `GaussianSampler` it
/// degenerates to best-of-K Gaussian selection (an ablation arm), with
/// [`crate::sampler::LdsdSampler`] it is the paper's full method.
///
/// Batched form: the K candidate probes ride one `loss_k` dispatch;
/// `consume` spends one extra `loss_dir` at `-tau` along the selected
/// direction (line 5 reuses the `+tau` loss from the batch), then feeds
/// the *same* probe matrix to the sampler's REINFORCE update — no second
/// pass over K vectors.
pub struct LdsdEstimator<S: DirectionSampler> {
    /// Direction policy (learnable for [`crate::sampler::LdsdSampler`]).
    pub sampler: S,
    /// Finite-difference scale.
    pub tau: f32,
    /// Number of candidate directions per step.
    pub k: usize,
    dirs: Vec<f32>,
    losses: Vec<f64>,
    exec: ExecContext,
    proposed: bool,
}

impl<S: DirectionSampler> LdsdEstimator<S> {
    /// Build with a direction sampler, finite-difference scale and
    /// candidate count (k >= 1).
    pub fn new(sampler: S, tau: f32, k: usize) -> Self {
        assert!(k >= 1);
        let d = sampler.dim();
        Self {
            sampler,
            tau,
            k,
            dirs: vec![0.0; k * d],
            losses: Vec::with_capacity(k + 1),
            exec: ExecContext::serial(),
            proposed: false,
        }
    }

    /// The underlying direction sampler (policy diagnostics).
    pub fn sampler(&self) -> &S {
        &self.sampler
    }
}

impl<S: DirectionSampler> GradEstimator for LdsdEstimator<S> {
    fn propose(&mut self) -> Result<ProbeBatch<'_>> {
        self.sampler.sample(&mut self.dirs, self.k);
        self.proposed = true;
        Ok(ProbeBatch { dirs: &self.dirs, k: self.k, tau: self.tau })
    }

    fn consume(
        &mut self,
        oracle: &mut dyn Oracle,
        losses: &[f64],
        g: &mut [f32],
    ) -> Result<Estimate> {
        if !self.proposed {
            bail!("ldsd_bestofk: consume without a matching propose");
        }
        if losses.len() != self.k {
            bail!(
                "ldsd_bestofk: expected {} probe losses, got {}",
                self.k,
                losses.len()
            );
        }
        self.proposed = false;
        let d = self.sampler.dim();
        // greedy selection (line 4)
        let best = losses
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
            .map(|(i, _)| i)
            .unwrap_or(0);
        let vstar = &self.dirs[best * d..(best + 1) * d];
        // central difference along v* (line 5); f(x + tau v*) is reused
        let f_minus = oracle.loss_dir(vstar, -self.tau)?;
        let coeff = (losses[best] - f_minus) / (2.0 * self.tau as f64);
        let cf = coeff as f32;
        self.exec.for_each_shard_mut(g, |_, start, gb| {
            for (i, gi) in gb.iter_mut().enumerate() {
                *gi = cf * vstar[start + i];
            }
        });
        // policy update from all K probes (lines 6/8), reusing the probe
        // matrix the batch was evaluated on
        self.sampler.observe(&self.dirs, losses, self.k);
        self.losses.clear();
        self.losses.extend_from_slice(losses);
        self.losses.push(f_minus);
        Ok(Estimate {
            calls: self.k as u64 + 1,
            loss: losses[best],
            selected: Some(best),
            fd_coeff: coeff,
        })
    }

    fn set_exec(&mut self, ctx: ExecContext) {
        self.sampler.set_exec(ctx.clone());
        self.exec = ctx;
    }

    fn last_losses(&self) -> &[f64] {
        &self.losses
    }

    fn calls_per_step(&self) -> u64 {
        self.k as u64 + 1
    }

    fn name(&self) -> &str {
        "ldsd_bestofk"
    }

    fn state_bytes(&self) -> usize {
        self.dirs.len() * 4 + self.sampler.state_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::oracle::QuadraticOracle;
    use crate::sampler::{GaussianSampler, LdsdConfig, LdsdSampler};
    use crate::tensor::{axpy, cosine};

    fn quad(d: usize) -> QuadraticOracle {
        // f(x) = 0.5 ||x - 1||^2 from x = 0: grad = x - 1 = -1
        QuadraticOracle::new(vec![1.0; d], vec![1.0; d], vec![0.0; d])
    }

    #[test]
    fn central_k1_matches_directional_derivative() {
        let d = 24;
        let mut o = quad(d);
        let mut est = CentralK1Estimator::new(GaussianSampler::new(d, 1), 1e-3);
        let mut g = vec![0.0f32; d];
        let e = est.estimate(&mut o, &mut g).unwrap();
        assert_eq!(e.calls, 2);
        // for the quadratic, fd along v is exact: coeff = <grad, v>
        // (est.dirs row 0 is v; zip stops at d)
        let true_grad = vec![-1.0f32; d];
        let vdotg: f32 = true_grad
            .iter()
            .zip(est.dirs.iter())
            .map(|(a, b)| a * b)
            .sum();
        assert!(
            ((e.fd_coeff as f32) - vdotg).abs() < 1e-2 * (1.0 + vdotg.abs()),
            "coeff {} vs <g,v> {vdotg}",
            e.fd_coeff
        );
    }

    #[test]
    fn central_k1_probe_matrix_is_plus_minus_v() {
        let d = 8;
        let mut est = CentralK1Estimator::new(GaussianSampler::new(d, 3), 1e-3);
        let batch = est.propose().unwrap();
        assert_eq!(batch.k, 2);
        assert_eq!(batch.dirs.len(), 2 * d);
        for i in 0..d {
            assert_eq!(batch.dirs[d + i], -batch.dirs[i]);
        }
    }

    #[test]
    fn forward_avg_unbiasedish_over_many_steps() {
        let d = 8;
        let mut o = quad(d);
        let mut est = ForwardAvgEstimator::new(GaussianSampler::new(d, 2), 1e-3, 4);
        let mut g = vec![0.0f32; d];
        let mut acc = vec![0.0f32; d];
        let reps = 400;
        for _ in 0..reps {
            est.estimate(&mut o, &mut g).unwrap();
            axpy(1.0 / reps as f32, &g, &mut acc);
        }
        let true_grad = vec![-1.0f32; d];
        let cos = cosine(&acc, &true_grad);
        assert!(cos > 0.9, "averaged estimate should align with grad, cos={cos}");
    }

    #[test]
    fn propose_consume_split_matches_estimate() {
        // Driving the two phases by hand (per-probe loss_dir dispatch)
        // must produce the same estimate as the fused path with the same
        // sampler stream.
        let d = 16;
        let k = 5;
        let mut o1 = quad(d);
        let mut fused = LdsdEstimator::new(
            LdsdSampler::new(d, 11, LdsdConfig::default()),
            1e-3,
            k,
        );
        let mut g1 = vec![0.0f32; d];
        let e1 = fused.estimate(&mut o1, &mut g1).unwrap();

        let mut o2 = quad(d);
        let mut split = LdsdEstimator::new(
            LdsdSampler::new(d, 11, LdsdConfig::default()),
            1e-3,
            k,
        );
        let mut g2 = vec![0.0f32; d];
        let losses = {
            let batch = split.propose().unwrap();
            (0..batch.k)
                .map(|i| {
                    o2.loss_dir(&batch.dirs[i * d..(i + 1) * d], batch.tau)
                        .unwrap()
                })
                .collect::<Vec<f64>>()
        };
        let e2 = split.consume(&mut o2, &losses, &mut g2).unwrap();

        assert_eq!(e1.selected, e2.selected);
        assert_eq!(e1.calls, e2.calls);
        assert_eq!(o1.oracle_calls(), o2.oracle_calls());
        for (a, b) in g1.iter().zip(g2.iter()) {
            assert!((a - b).abs() <= 1e-5 * (1.0 + a.abs()), "{a} vs {b}");
        }
    }

    #[test]
    fn estimate_with_reuses_buffer_and_matches_estimate() {
        let d = 16;
        let mut o1 = quad(d);
        let mut e1 = LdsdEstimator::new(
            LdsdSampler::new(d, 4, LdsdConfig::default()),
            1e-3,
            3,
        );
        let mut o2 = quad(d);
        let mut e2 = LdsdEstimator::new(
            LdsdSampler::new(d, 4, LdsdConfig::default()),
            1e-3,
            3,
        );
        let mut g1 = vec![0.0f32; d];
        let mut g2 = vec![0.0f32; d];
        let mut buf = Vec::new();
        for _ in 0..5 {
            let a = e1.estimate(&mut o1, &mut g1).unwrap();
            let b = e2.estimate_with(&mut o2, &mut g2, &mut buf).unwrap();
            assert_eq!(a.selected, b.selected);
            assert_eq!(a.loss.to_bits(), b.loss.to_bits());
            assert_eq!(g1, g2);
            assert_eq!(buf.len(), 3, "buffer holds the K batch losses");
        }
        let cap = buf.capacity();
        e2.estimate_with(&mut o2, &mut g2, &mut buf).unwrap();
        assert_eq!(buf.capacity(), cap, "steady-state steps must not realloc");
    }

    #[test]
    fn consume_rejects_wrong_loss_count() {
        let d = 8;
        let mut o = quad(d);
        let mut est = LdsdEstimator::new(
            LdsdSampler::new(d, 1, LdsdConfig::default()),
            1e-3,
            3,
        );
        let mut g = vec![0.0f32; d];
        let _ = est.propose().unwrap();
        assert!(est.consume(&mut o, &[0.1, 0.2], &mut g).is_err());
    }

    #[test]
    fn consume_requires_propose() {
        // Combining without a propose (or twice for one propose) would
        // read a stale/zero probe matrix; both must be rejected.
        let d = 8;
        let mut o = quad(d);
        let mut est = LdsdEstimator::new(
            LdsdSampler::new(d, 1, LdsdConfig::default()),
            1e-3,
            3,
        );
        let mut g = vec![0.0f32; d];
        let losses = [0.1f64, 0.2, 0.3];
        assert!(est.consume(&mut o, &losses, &mut g).is_err());
        let _ = est.propose().unwrap();
        assert!(est.consume(&mut o, &losses, &mut g).is_ok());
        assert!(
            est.consume(&mut o, &losses, &mut g).is_err(),
            "second consume for one propose must be rejected"
        );
    }

    #[test]
    fn ldsd_selects_lowest_probe() {
        let d = 16;
        let mut o = quad(d);
        let sampler = LdsdSampler::new(d, 3, LdsdConfig::default());
        let mut est = LdsdEstimator::new(sampler, 1e-3, 5);
        let mut g = vec![0.0f32; d];
        let e = est.estimate(&mut o, &mut g).unwrap();
        assert_eq!(e.calls, 6);
        // last_losses = the 5 batch probes + the follow-up -tau evaluation
        assert_eq!(est.last_losses().len(), 6);
        let probes = &est.last_losses()[..5];
        let best = e.selected.unwrap();
        for p in probes {
            assert!(probes[best] <= *p);
        }
        assert_eq!(e.loss.to_bits(), probes[best].to_bits());
    }

    #[test]
    fn ldsd_gradient_points_downhill() {
        // A step along -g must not increase the quadratic's loss (descent
        // direction on average); check over several steps.
        let d = 32;
        let mut o = quad(d);
        let sampler = LdsdSampler::new(d, 5, LdsdConfig::default());
        let mut est = LdsdEstimator::new(sampler, 1e-3, 5);
        let mut g = vec![0.0f32; d];
        let mut downhill = 0;
        let reps = 30;
        for _ in 0..reps {
            est.estimate(&mut o, &mut g).unwrap();
            let zero = vec![0.0f32; d];
            let f0 = o.loss_dir(&zero, 0.0).unwrap();
            let f1 = o.loss_dir(&g, -1e-2).unwrap();
            if f1 <= f0 {
                downhill += 1;
            }
        }
        assert!(downhill >= reps * 2 / 3, "downhill {downhill}/{reps}");
    }

    #[test]
    fn budget_accounting_exact() {
        let d = 8;
        let mut o = quad(d);
        let mut est = LdsdEstimator::new(
            LdsdSampler::new(d, 1, LdsdConfig::default()),
            1e-3,
            3,
        );
        let mut g = vec![0.0f32; d];
        let before = o.oracle_calls();
        let e = est.estimate(&mut o, &mut g).unwrap();
        assert_eq!(o.oracle_calls() - before, e.calls);
        assert_eq!(e.calls, est.calls_per_step());
    }

    #[test]
    fn estimators_bitwise_identical_across_thread_counts() {
        // Same seed, same shard length: a serial and an 8-thread estimator
        // must produce bit-identical gradients and probe losses.
        let d = 3000;
        let k = 5;
        let mk = |threads: usize| {
            let mut est = LdsdEstimator::new(
                LdsdSampler::new(d, 21, LdsdConfig::default()),
                1e-3,
                k,
            );
            est.set_exec(
                crate::exec::ExecContext::new(threads).with_shard_len(256),
            );
            est
        };
        let mut o1 = quad(d);
        let mut o8 = quad(d);
        let mut e1 = mk(1);
        let mut e8 = mk(8);
        let mut g1 = vec![0.0f32; d];
        let mut g8 = vec![0.0f32; d];
        for _ in 0..3 {
            let a = e1.estimate(&mut o1, &mut g1).unwrap();
            let b = e8.estimate(&mut o8, &mut g8).unwrap();
            assert_eq!(a.selected, b.selected);
            assert_eq!(a.loss.to_bits(), b.loss.to_bits());
            for (x, y) in g1.iter().zip(g8.iter()) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
            for (x, y) in e1.last_losses().iter().zip(e8.last_losses().iter()) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }
}
