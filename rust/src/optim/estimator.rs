//! Gradient estimators: forward evaluations -> gradient surrogate.
//!
//! All estimators write a dense `g` into a caller-provided buffer so the
//! base optimizers are strategy-agnostic (the paper's plug-in claim), and
//! report exactly how many oracle calls they spent (the §5.1 budget-fair
//! protocol charges estimators by calls, not iterations).
//!
//! # Two-phase batched estimation over a `ProbeSource`
//!
//! Estimation is split into a `propose`/`consume` flow around the K x d
//! probe matrix, which lives behind a [`ProbeSource`] (DESIGN.md §10) —
//! materialized (the stored matrix) or streamed (seed replay, no matrix):
//!
//! 1. [`GradEstimator::propose`] advances the estimator's probe source to
//!    this step's directions and describes the required evaluations (no
//!    oracle calls yet);
//! 2. the caller evaluates the batch — normally one fused
//!    [`Oracle::loss_probes`] dispatch, or K separate `loss_dir` calls on
//!    the materialized matrix for per-probe A/B benchmarking
//!    (`ProbeDispatch` in [`crate::train`]);
//! 3. [`GradEstimator::consume`] combines the probe losses into `g`
//!    through the source's fused combine kernels (plus at most one
//!    follow-up point evaluation: the forward-difference base loss, or
//!    Algorithm 2's central-difference probe at `-tau` along the selected
//!    direction).
//!
//! [`GradEstimator::estimate`] is the one-call convenience that wires the
//! three steps together; [`GradEstimator::estimate_with`] is the hot-path
//! variant that reuses a caller-provided probe-loss buffer across steps.
//!
//! Every O(d) and O(K d) pass goes through the estimator's installed
//! [`ExecContext`], so combines run shard-parallel with results bitwise
//! identical for any worker count (DESIGN.md §9) — and, by the probe
//! source contract, identical across storage modes too.  The per-step
//! probe losses are kept in a reusable buffer exposed via
//! [`GradEstimator::last_losses`].

use anyhow::{bail, Result};

use crate::exec::ExecContext;
use crate::oracle::Oracle;
use crate::probe::{
    build_source, BoxedSampler, ProbeLayout, ProbeSource, ProbeStorage,
};
use crate::sampler::DirectionSampler;

/// One batch of probe evaluations requested by [`GradEstimator::propose`]:
/// `k` rows, each to be evaluated at `f(x + tau * dir)`.
#[derive(Clone, Copy, Debug)]
pub struct ProbeBatch<'a> {
    /// Row-major `k x d` direction matrix when the estimator's probe
    /// source materializes one (valid until the next `propose`); `None`
    /// on the streamed path, where rows are replayed on demand through
    /// [`GradEstimator::probes`].
    pub dirs: Option<&'a [f32]>,
    /// Number of probe rows.
    pub k: usize,
    /// Finite-difference scale each row is evaluated at.
    pub tau: f32,
}

/// Outcome of one estimation step.
///
/// The full per-step loss vector lives in the estimator's reusable buffer
/// ([`GradEstimator::last_losses`]); this struct carries only the scalars
/// so the per-step path stays allocation-free.
#[derive(Clone, Copy, Debug)]
pub struct Estimate {
    /// Oracle calls consumed by this step.
    pub calls: u64,
    /// Scalar training-loss proxy for this step: the selected probe's
    /// loss (Algorithm 2), the base loss (forward averaging), or the
    /// `+tau` probe (central difference).
    pub loss: f64,
    /// Index of the selected direction (Algorithm 2 line 4), if any.
    pub selected: Option<usize>,
    /// The finite-difference coefficient applied to the selected direction
    /// (0 when `g` is an average).
    pub fd_coeff: f64,
}

/// Turns forward evaluations into a dense gradient surrogate.
pub trait GradEstimator {
    /// Phase 1: advance the probe source to this step's directions and
    /// describe the required evaluations.  Performs no oracle calls.
    fn propose(&mut self) -> Result<ProbeBatch<'_>>;

    /// The probe source holding (or replaying) the last proposed batch —
    /// the handle [`Oracle::loss_probes`] evaluates against.
    fn probes(&self) -> &dyn ProbeSource;

    /// Mutable access to the probe source (snapshot restore: the trainer
    /// reinstates the sampler's RNG step label and policy mean through
    /// it).
    fn probes_mut(&mut self) -> &mut dyn ProbeSource;

    /// Phase 2: combine the `losses` of the last proposed batch (in row
    /// order) into `g` (len d).  May spend extra oracle calls for point
    /// evaluations that cannot be batched (see the module docs); the
    /// returned [`Estimate::calls`] covers the whole step including the
    /// batch itself.
    ///
    /// Each `consume` must be paired with a preceding call to
    /// [`GradEstimator::propose`]: combining without one (or twice for
    /// one propose) would silently read a stale probe step, so it is an
    /// error.
    fn consume(
        &mut self,
        oracle: &mut dyn Oracle,
        losses: &[f64],
        g: &mut [f32],
    ) -> Result<Estimate>;

    /// Estimate grad f(x) into `g` (len d) in one call: propose, evaluate
    /// the batch via one fused [`Oracle::loss_probes`] dispatch, consume.
    /// The oracle's current batch must be set by the caller.
    fn estimate(&mut self, oracle: &mut dyn Oracle, g: &mut [f32]) -> Result<Estimate> {
        let mut scratch = Vec::new();
        self.estimate_with(oracle, g, &mut scratch)
    }

    /// [`GradEstimator::estimate`] with a caller-provided probe-loss
    /// buffer, reused across steps on the train-loop hot path.
    fn estimate_with(
        &mut self,
        oracle: &mut dyn Oracle,
        g: &mut [f32],
        probe_losses: &mut Vec<f64>,
    ) -> Result<Estimate> {
        let (k, tau) = {
            let batch = self.propose()?;
            (batch.k, batch.tau)
        };
        oracle.loss_probes(self.probes(), k, tau, probe_losses)?;
        self.consume(oracle, probe_losses, g)
    }

    /// Install the shard-parallel execution context used by the combine
    /// kernels, and forwarded to the estimator's probe source + sampler.
    fn set_exec(&mut self, _ctx: ExecContext) {}

    /// The probe losses of the last completed `consume` (diagnostics):
    /// batch losses in row order, followed by any extra point evaluations
    /// that step spent.  Borrowed from a buffer reused across steps.
    fn last_losses(&self) -> &[f64] {
        &[]
    }

    /// Oracle calls one step consumes (for budget planning).
    fn calls_per_step(&self) -> u64;

    /// Short identifier used in run labels.
    fn name(&self) -> &str;

    /// Bytes of persistent estimator state (memory accounting): probe
    /// representation + sampler policy state.
    fn state_bytes(&self) -> usize;
}

/// Classical ZO central difference with a single probe direction
/// (MeZO-style; the "Gaussian, 2 forwards, more iterations" row of
/// Table 1):  g = v * (f(x + tau v) - f(x - tau v)) / (2 tau).
///
/// Batched form: the probe source presents `[v; -v]` (2 x d,
/// [`ProbeLayout::CentralPair`]), so both sides of the central difference
/// ride one batch dispatch.
pub struct CentralK1Estimator {
    probes: Box<dyn ProbeSource>,
    tau: f32,
    losses: Vec<f64>,
    proposed: bool,
}

impl CentralK1Estimator {
    /// Build with a direction sampler and finite-difference scale on the
    /// materialized (reference) probe path.
    pub fn new<S: DirectionSampler + Send + Sync + 'static>(sampler: S, tau: f32) -> Self {
        Self::with_storage(sampler, tau, ProbeStorage::Materialized)
            .expect("materialized probes are always constructible")
    }

    /// [`CentralK1Estimator::new`] with an explicit probe storage choice.
    pub fn with_storage<S: DirectionSampler + Send + Sync + 'static>(
        sampler: S,
        tau: f32,
        storage: ProbeStorage,
    ) -> Result<Self> {
        let sampler: BoxedSampler = Box::new(sampler);
        let probes = build_source(storage, sampler, ProbeLayout::CentralPair, 2)?;
        Ok(Self { probes, tau, losses: Vec::with_capacity(2), proposed: false })
    }
}

impl GradEstimator for CentralK1Estimator {
    fn propose(&mut self) -> Result<ProbeBatch<'_>> {
        self.probes.advance();
        self.proposed = true;
        Ok(ProbeBatch { dirs: self.probes.dirs(), k: 2, tau: self.tau })
    }

    fn probes(&self) -> &dyn ProbeSource {
        &*self.probes
    }

    fn probes_mut(&mut self) -> &mut dyn ProbeSource {
        &mut *self.probes
    }

    fn consume(
        &mut self,
        _oracle: &mut dyn Oracle,
        losses: &[f64],
        g: &mut [f32],
    ) -> Result<Estimate> {
        if !self.proposed {
            bail!("central_k1: consume without a matching propose");
        }
        if losses.len() != 2 {
            bail!("central_k1: expected 2 probe losses, got {}", losses.len());
        }
        self.proposed = false;
        let (fp, fm) = (losses[0], losses[1]);
        let coeff = (fp - fm) / (2.0 * self.tau as f64);
        self.probes.scaled_row(0, coeff as f32, g);
        self.losses.clear();
        self.losses.push(fp);
        self.losses.push(fm);
        Ok(Estimate { calls: 2, loss: fp, selected: Some(0), fd_coeff: coeff })
    }

    fn set_exec(&mut self, ctx: ExecContext) {
        self.probes.set_exec(ctx);
    }

    fn last_losses(&self) -> &[f64] {
        &self.losses
    }

    fn calls_per_step(&self) -> u64 {
        2
    }

    fn name(&self) -> &str {
        "central_k1"
    }

    fn state_bytes(&self) -> usize {
        self.probes.probe_state_bytes() + self.probes.sampler().state_bytes()
    }
}

/// Monte-Carlo forward-difference averaging (eq. 5 with one-point probes;
/// the "Gaussian, 6 forwards, same iterations" row):
/// g = (1/K) sum_i v_i (f(x + tau v_i) - f(x)) / tau.
///
/// Batched form: all K probes go through one batch dispatch; the base
/// loss f(x) is the one point evaluation `consume` performs, and the
/// combine is a single fused reduce over the probe source.
pub struct ForwardAvgEstimator {
    probes: Box<dyn ProbeSource>,
    tau: f32,
    k: usize,
    weights: Vec<f32>,
    losses: Vec<f64>,
    zero: Vec<f32>,
    proposed: bool,
}

impl ForwardAvgEstimator {
    /// Build with a direction sampler, finite-difference scale and probe
    /// count (k >= 1) on the materialized (reference) probe path.
    pub fn new<S: DirectionSampler + Send + Sync + 'static>(sampler: S, tau: f32, k: usize) -> Self {
        Self::with_storage(sampler, tau, k, ProbeStorage::Materialized)
            .expect("materialized probes are always constructible")
    }

    /// [`ForwardAvgEstimator::new`] with an explicit probe storage choice.
    pub fn with_storage<S: DirectionSampler + Send + Sync + 'static>(
        sampler: S,
        tau: f32,
        k: usize,
        storage: ProbeStorage,
    ) -> Result<Self> {
        assert!(k >= 1);
        let sampler: BoxedSampler = Box::new(sampler);
        let d = sampler.dim();
        let probes = build_source(storage, sampler, ProbeLayout::Direct, k)?;
        Ok(Self {
            probes,
            tau,
            k,
            weights: Vec::with_capacity(k),
            losses: Vec::with_capacity(k + 1),
            zero: vec![0.0; d],
            proposed: false,
        })
    }
}

impl GradEstimator for ForwardAvgEstimator {
    fn propose(&mut self) -> Result<ProbeBatch<'_>> {
        self.probes.advance();
        self.proposed = true;
        Ok(ProbeBatch { dirs: self.probes.dirs(), k: self.k, tau: self.tau })
    }

    fn probes(&self) -> &dyn ProbeSource {
        &*self.probes
    }

    fn probes_mut(&mut self) -> &mut dyn ProbeSource {
        &mut *self.probes
    }

    fn consume(
        &mut self,
        oracle: &mut dyn Oracle,
        losses: &[f64],
        g: &mut [f32],
    ) -> Result<Estimate> {
        if !self.proposed {
            bail!("forward_avg: consume without a matching propose");
        }
        if losses.len() != self.k {
            bail!(
                "forward_avg: expected {} probe losses, got {}",
                self.k,
                losses.len()
            );
        }
        self.proposed = false;
        let f_base = oracle.loss_dir(&self.zero, 0.0)?;
        let denom = self.k as f64 * self.tau as f64;
        self.weights.clear();
        self.weights
            .extend(losses.iter().map(|l| ((l - f_base) / denom) as f32));
        self.probes.combine(&self.weights, g);
        // trait contract: batch losses in row order first, then the extra
        // point evaluation (here the forward-difference base loss)
        self.losses.clear();
        self.losses.extend_from_slice(losses);
        self.losses.push(f_base);
        Ok(Estimate {
            calls: self.k as u64 + 1,
            loss: f_base,
            selected: None,
            fd_coeff: 0.0,
        })
    }

    fn set_exec(&mut self, ctx: ExecContext) {
        self.probes.set_exec(ctx);
    }

    fn last_losses(&self) -> &[f64] {
        &self.losses
    }

    fn calls_per_step(&self) -> u64 {
        self.k as u64 + 1
    }

    fn name(&self) -> &str {
        "forward_avg"
    }

    fn state_bytes(&self) -> usize {
        self.probes.probe_state_bytes()
            + (self.weights.capacity() + self.zero.len()) * 4
            + self.probes.sampler().state_bytes()
    }
}

/// Algorithm 2 (ZO-LDSD): sample K candidates from the (learnable) policy,
/// greedily select the probe with the lowest loss, take a central
/// difference along it, and update the policy from all K probe losses.
///
/// Works with *any* [`DirectionSampler`]; with `GaussianSampler` it
/// degenerates to best-of-K Gaussian selection (an ablation arm), with
/// [`crate::sampler::LdsdSampler`] it is the paper's full method.
///
/// Batched form: the K candidate probes ride one batch dispatch; `consume`
/// spends one extra `loss_dir` at `-tau` along the selected direction
/// (line 5 reuses the `+tau` loss from the batch), then feeds the same
/// probe step to the sampler's REINFORCE update through the probe source —
/// on the streamed path the update replays the probe shards instead of
/// re-reading a stored matrix.
pub struct LdsdEstimator {
    probes: Box<dyn ProbeSource>,
    tau: f32,
    k: usize,
    losses: Vec<f64>,
    exec: ExecContext,
    proposed: bool,
}

impl LdsdEstimator {
    /// Build with a direction sampler, finite-difference scale and
    /// candidate count (k >= 1) on the materialized (reference) probe
    /// path.
    pub fn new<S: DirectionSampler + Send + Sync + 'static>(sampler: S, tau: f32, k: usize) -> Self {
        Self::with_storage(sampler, tau, k, ProbeStorage::Materialized)
            .expect("materialized probes are always constructible")
    }

    /// [`LdsdEstimator::new`] with an explicit probe storage choice.
    pub fn with_storage<S: DirectionSampler + Send + Sync + 'static>(
        sampler: S,
        tau: f32,
        k: usize,
        storage: ProbeStorage,
    ) -> Result<Self> {
        assert!(k >= 1);
        let sampler: BoxedSampler = Box::new(sampler);
        let probes = build_source(storage, sampler, ProbeLayout::Direct, k)?;
        Ok(Self {
            probes,
            tau,
            k,
            losses: Vec::with_capacity(k + 1),
            exec: ExecContext::serial(),
            proposed: false,
        })
    }

    /// The underlying direction sampler (policy diagnostics).
    pub fn sampler(&self) -> &dyn DirectionSampler {
        self.probes.sampler()
    }
}

impl GradEstimator for LdsdEstimator {
    fn propose(&mut self) -> Result<ProbeBatch<'_>> {
        self.probes.advance();
        self.proposed = true;
        Ok(ProbeBatch { dirs: self.probes.dirs(), k: self.k, tau: self.tau })
    }

    fn probes(&self) -> &dyn ProbeSource {
        &*self.probes
    }

    fn probes_mut(&mut self) -> &mut dyn ProbeSource {
        &mut *self.probes
    }

    fn consume(
        &mut self,
        oracle: &mut dyn Oracle,
        losses: &[f64],
        g: &mut [f32],
    ) -> Result<Estimate> {
        if !self.proposed {
            bail!("ldsd_bestofk: consume without a matching propose");
        }
        if losses.len() != self.k {
            bail!(
                "ldsd_bestofk: expected {} probe losses, got {}",
                self.k,
                losses.len()
            );
        }
        self.proposed = false;
        let d = self.probes.dim();
        // greedy selection (line 4)
        let best = losses
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
            .map(|(i, _)| i)
            .unwrap_or(0);
        // central difference along v* (line 5); f(x + tau v*) is reused.
        // Materialized sources hand the oracle the stored row; streamed
        // sources replay v* into the caller's g buffer (the one O(d)
        // vector already in play) — no extra allocation either way.
        let f_minus = match self.probes.dirs() {
            Some(dirs) => oracle.loss_dir(&dirs[best * d..(best + 1) * d], -self.tau)?,
            None => {
                self.probes.scaled_row(best, 1.0, g);
                oracle.loss_dir(g, -self.tau)?
            }
        };
        let coeff = (losses[best] - f_minus) / (2.0 * self.tau as f64);
        let cf = coeff as f32;
        match self.probes.dirs() {
            Some(_) => self.probes.scaled_row(best, cf, g),
            None => {
                // g already holds v* (replayed above): scale in place, one
                // multiply per element — same product as cf * v bitwise
                self.exec.for_each_shard_mut(g, |_, _, gb| {
                    for v in gb.iter_mut() {
                        *v *= cf;
                    }
                });
            }
        }
        // policy update from all K probes (lines 6/8) through the probe
        // source: materialized feeds the stored matrix, streamed replays
        self.probes.observe(losses);
        self.losses.clear();
        self.losses.extend_from_slice(losses);
        self.losses.push(f_minus);
        Ok(Estimate {
            calls: self.k as u64 + 1,
            loss: losses[best],
            selected: Some(best),
            fd_coeff: coeff,
        })
    }

    fn set_exec(&mut self, ctx: ExecContext) {
        self.probes.set_exec(ctx.clone());
        self.exec = ctx;
    }

    fn last_losses(&self) -> &[f64] {
        &self.losses
    }

    fn calls_per_step(&self) -> u64 {
        self.k as u64 + 1
    }

    fn name(&self) -> &str {
        "ldsd_bestofk"
    }

    fn state_bytes(&self) -> usize {
        self.probes.probe_state_bytes() + self.probes.sampler().state_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::oracle::QuadraticOracle;
    use crate::sampler::{GaussianSampler, LdsdConfig, LdsdSampler};
    use crate::tensor::{axpy, cosine};

    fn quad(d: usize) -> QuadraticOracle {
        // f(x) = 0.5 ||x - 1||^2 from x = 0: grad = x - 1 = -1
        QuadraticOracle::new(vec![1.0; d], vec![1.0; d], vec![0.0; d])
    }

    #[test]
    fn central_k1_matches_directional_derivative() {
        let d = 24;
        let mut o = quad(d);
        let mut est = CentralK1Estimator::new(GaussianSampler::new(d, 1), 1e-3);
        let mut g = vec![0.0f32; d];
        let e = est.estimate(&mut o, &mut g).unwrap();
        assert_eq!(e.calls, 2);
        // for the quadratic, fd along v is exact: coeff = <grad, v>
        // (probe row 0 is v)
        let v = est.probes().dirs().unwrap()[..d].to_vec();
        let true_grad = vec![-1.0f32; d];
        let vdotg: f32 = true_grad.iter().zip(v.iter()).map(|(a, b)| a * b).sum();
        assert!(
            ((e.fd_coeff as f32) - vdotg).abs() < 1e-2 * (1.0 + vdotg.abs()),
            "coeff {} vs <g,v> {vdotg}",
            e.fd_coeff
        );
    }

    #[test]
    fn central_k1_probe_matrix_is_plus_minus_v() {
        let d = 8;
        let mut est = CentralK1Estimator::new(GaussianSampler::new(d, 3), 1e-3);
        let batch = est.propose().unwrap();
        assert_eq!(batch.k, 2);
        let dirs = batch.dirs.unwrap();
        assert_eq!(dirs.len(), 2 * d);
        for i in 0..d {
            assert_eq!(dirs[d + i], -dirs[i]);
        }
    }

    #[test]
    fn forward_avg_unbiasedish_over_many_steps() {
        let d = 8;
        let mut o = quad(d);
        let mut est = ForwardAvgEstimator::new(GaussianSampler::new(d, 2), 1e-3, 4);
        let mut g = vec![0.0f32; d];
        let mut acc = vec![0.0f32; d];
        let reps = 400;
        for _ in 0..reps {
            est.estimate(&mut o, &mut g).unwrap();
            axpy(1.0 / reps as f32, &g, &mut acc);
        }
        let true_grad = vec![-1.0f32; d];
        let cos = cosine(&acc, &true_grad);
        assert!(cos > 0.9, "averaged estimate should align with grad, cos={cos}");
    }

    #[test]
    fn propose_consume_split_matches_estimate() {
        // Driving the two phases by hand (per-probe loss_dir dispatch)
        // must produce the same estimate as the fused path with the same
        // sampler stream.
        let d = 16;
        let k = 5;
        let mut o1 = quad(d);
        let mut fused = LdsdEstimator::new(
            LdsdSampler::new(d, 11, LdsdConfig::default()),
            1e-3,
            k,
        );
        let mut g1 = vec![0.0f32; d];
        let e1 = fused.estimate(&mut o1, &mut g1).unwrap();

        let mut o2 = quad(d);
        let mut split = LdsdEstimator::new(
            LdsdSampler::new(d, 11, LdsdConfig::default()),
            1e-3,
            k,
        );
        let mut g2 = vec![0.0f32; d];
        let losses = {
            let batch = split.propose().unwrap();
            let dirs = batch.dirs.unwrap();
            (0..batch.k)
                .map(|i| o2.loss_dir(&dirs[i * d..(i + 1) * d], batch.tau).unwrap())
                .collect::<Vec<f64>>()
        };
        let e2 = split.consume(&mut o2, &losses, &mut g2).unwrap();

        assert_eq!(e1.selected, e2.selected);
        assert_eq!(e1.calls, e2.calls);
        assert_eq!(o1.oracle_calls(), o2.oracle_calls());
        for (a, b) in g1.iter().zip(g2.iter()) {
            assert!((a - b).abs() <= 1e-5 * (1.0 + a.abs()), "{a} vs {b}");
        }
    }

    #[test]
    fn estimate_with_reuses_buffer_and_matches_estimate() {
        let d = 16;
        let mut o1 = quad(d);
        let mut e1 = LdsdEstimator::new(
            LdsdSampler::new(d, 4, LdsdConfig::default()),
            1e-3,
            3,
        );
        let mut o2 = quad(d);
        let mut e2 = LdsdEstimator::new(
            LdsdSampler::new(d, 4, LdsdConfig::default()),
            1e-3,
            3,
        );
        let mut g1 = vec![0.0f32; d];
        let mut g2 = vec![0.0f32; d];
        let mut buf = Vec::new();
        for _ in 0..5 {
            let a = e1.estimate(&mut o1, &mut g1).unwrap();
            let b = e2.estimate_with(&mut o2, &mut g2, &mut buf).unwrap();
            assert_eq!(a.selected, b.selected);
            assert_eq!(a.loss.to_bits(), b.loss.to_bits());
            assert_eq!(g1, g2);
            assert_eq!(buf.len(), 3, "buffer holds the K batch losses");
        }
        let cap = buf.capacity();
        e2.estimate_with(&mut o2, &mut g2, &mut buf).unwrap();
        assert_eq!(buf.capacity(), cap, "steady-state steps must not realloc");
    }

    #[test]
    fn consume_rejects_wrong_loss_count() {
        let d = 8;
        let mut o = quad(d);
        let mut est = LdsdEstimator::new(
            LdsdSampler::new(d, 1, LdsdConfig::default()),
            1e-3,
            3,
        );
        let mut g = vec![0.0f32; d];
        let _ = est.propose().unwrap();
        assert!(est.consume(&mut o, &[0.1, 0.2], &mut g).is_err());
    }

    #[test]
    fn consume_requires_propose() {
        // Combining without a propose (or twice for one propose) would
        // read a stale probe step; both must be rejected.
        let d = 8;
        let mut o = quad(d);
        let mut est = LdsdEstimator::new(
            LdsdSampler::new(d, 1, LdsdConfig::default()),
            1e-3,
            3,
        );
        let mut g = vec![0.0f32; d];
        let losses = [0.1f64, 0.2, 0.3];
        assert!(est.consume(&mut o, &losses, &mut g).is_err());
        let _ = est.propose().unwrap();
        assert!(est.consume(&mut o, &losses, &mut g).is_ok());
        assert!(
            est.consume(&mut o, &losses, &mut g).is_err(),
            "second consume for one propose must be rejected"
        );
    }

    #[test]
    fn ldsd_selects_lowest_probe() {
        let d = 16;
        let mut o = quad(d);
        let sampler = LdsdSampler::new(d, 3, LdsdConfig::default());
        let mut est = LdsdEstimator::new(sampler, 1e-3, 5);
        let mut g = vec![0.0f32; d];
        let e = est.estimate(&mut o, &mut g).unwrap();
        assert_eq!(e.calls, 6);
        // last_losses = the 5 batch probes + the follow-up -tau evaluation
        assert_eq!(est.last_losses().len(), 6);
        let probes = &est.last_losses()[..5];
        let best = e.selected.unwrap();
        for p in probes {
            assert!(probes[best] <= *p);
        }
        assert_eq!(e.loss.to_bits(), probes[best].to_bits());
    }

    #[test]
    fn ldsd_gradient_points_downhill() {
        // A step along -g must not increase the quadratic's loss (descent
        // direction on average); check over several steps.
        let d = 32;
        let mut o = quad(d);
        let sampler = LdsdSampler::new(d, 5, LdsdConfig::default());
        let mut est = LdsdEstimator::new(sampler, 1e-3, 5);
        let mut g = vec![0.0f32; d];
        let mut downhill = 0;
        let reps = 30;
        for _ in 0..reps {
            est.estimate(&mut o, &mut g).unwrap();
            let zero = vec![0.0f32; d];
            let f0 = o.loss_dir(&zero, 0.0).unwrap();
            let f1 = o.loss_dir(&g, -1e-2).unwrap();
            if f1 <= f0 {
                downhill += 1;
            }
        }
        assert!(downhill >= reps * 2 / 3, "downhill {downhill}/{reps}");
    }

    #[test]
    fn budget_accounting_exact() {
        let d = 8;
        let mut o = quad(d);
        let mut est = LdsdEstimator::new(
            LdsdSampler::new(d, 1, LdsdConfig::default()),
            1e-3,
            3,
        );
        let mut g = vec![0.0f32; d];
        let before = o.oracle_calls();
        let e = est.estimate(&mut o, &mut g).unwrap();
        assert_eq!(o.oracle_calls() - before, e.calls);
        assert_eq!(e.calls, est.calls_per_step());
    }

    #[test]
    fn estimators_bitwise_identical_across_thread_counts() {
        // Same seed, same shard length: a serial and an 8-thread estimator
        // must produce bit-identical gradients and probe losses.
        let d = 3000;
        let k = 5;
        let mk = |threads: usize| {
            let mut est = LdsdEstimator::new(
                LdsdSampler::new(d, 21, LdsdConfig::default()),
                1e-3,
                k,
            );
            est.set_exec(
                crate::exec::ExecContext::new(threads).with_shard_len(256),
            );
            est
        };
        let mut o1 = quad(d);
        let mut o8 = quad(d);
        let mut e1 = mk(1);
        let mut e8 = mk(8);
        let mut g1 = vec![0.0f32; d];
        let mut g8 = vec![0.0f32; d];
        for _ in 0..3 {
            let a = e1.estimate(&mut o1, &mut g1).unwrap();
            let b = e8.estimate(&mut o8, &mut g8).unwrap();
            assert_eq!(a.selected, b.selected);
            assert_eq!(a.loss.to_bits(), b.loss.to_bits());
            for (x, y) in g1.iter().zip(g8.iter()) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
            for (x, y) in e1.last_losses().iter().zip(e8.last_losses().iter()) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    #[test]
    fn streamed_estimators_bitwise_match_materialized() {
        // The PR 3 acceptance property at the estimator level: same seed,
        // same shard geometry, both storage modes, any thread count — the
        // Estimates, gradients and probe losses are bit-for-bit equal.
        let d = 2000;
        let k = 5;
        let mk = |storage: ProbeStorage, threads: usize| {
            let mut est = LdsdEstimator::with_storage(
                LdsdSampler::new(d, 77, LdsdConfig::default()),
                1e-3,
                k,
                storage,
            )
            .unwrap();
            est.set_exec(crate::exec::ExecContext::new(threads).with_shard_len(192));
            est
        };
        let mut om = quad(d);
        let mut os = quad(d);
        os.set_exec(crate::exec::ExecContext::new(4).with_shard_len(192));
        let mut em = mk(ProbeStorage::Materialized, 1);
        let mut es = mk(ProbeStorage::Streamed, 4);
        assert_eq!(es.probes().label(), "streamed");
        let mut gm = vec![0.0f32; d];
        let mut gs = vec![0.0f32; d];
        for _ in 0..4 {
            let a = em.estimate(&mut om, &mut gm).unwrap();
            let b = es.estimate(&mut os, &mut gs).unwrap();
            assert_eq!(a.selected, b.selected);
            assert_eq!(a.calls, b.calls);
            assert_eq!(a.loss.to_bits(), b.loss.to_bits());
            assert_eq!(a.fd_coeff.to_bits(), b.fd_coeff.to_bits());
            for (x, y) in gm.iter().zip(gs.iter()) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
            for (x, y) in em.last_losses().iter().zip(es.last_losses().iter()) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
        // and the streamed estimator holds no K x d probe state
        assert!(es.state_bytes() < k * d * 4, "streamed must not hold K x d");
        assert_eq!(em.state_bytes(), k * d * 4 + d * 4); // matrix + mu
    }

    #[test]
    fn streamed_central_k1_matches_materialized() {
        let d = 600;
        let mut om = quad(d);
        let mut os = quad(d);
        let mut em = CentralK1Estimator::new(GaussianSampler::new(d, 9), 1e-3);
        let mut es = CentralK1Estimator::with_storage(
            GaussianSampler::new(d, 9),
            1e-3,
            ProbeStorage::Streamed,
        )
        .unwrap();
        let ctx = crate::exec::ExecContext::new(3).with_shard_len(128);
        em.set_exec(ctx.clone());
        es.set_exec(ctx.clone());
        os.set_exec(ctx);
        let mut gm = vec![0.0f32; d];
        let mut gs = vec![0.0f32; d];
        for _ in 0..3 {
            let a = em.estimate(&mut om, &mut gm).unwrap();
            let b = es.estimate(&mut os, &mut gs).unwrap();
            assert_eq!(a.loss.to_bits(), b.loss.to_bits());
            assert_eq!(a.fd_coeff.to_bits(), b.fd_coeff.to_bits());
            for (x, y) in gm.iter().zip(gs.iter()) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }
}
