//! Gradient estimators: forward evaluations -> gradient surrogate.
//!
//! All estimators write a dense `g` into a caller-provided buffer so the
//! base optimizers are strategy-agnostic (the paper's plug-in claim), and
//! report exactly how many oracle calls they spent (the §5.1 budget-fair
//! protocol charges estimators by calls, not iterations).
//!
//! # Two-phase batched estimation
//!
//! Estimation is split into a `propose`/`consume` flow around the K x d
//! probe matrix:
//!
//! 1. [`GradEstimator::propose`] fills the estimator's reusable row-major
//!    probe matrix from its [`DirectionSampler`] and returns it as a
//!    [`ProbeBatch`] (no oracle calls yet);
//! 2. the caller evaluates the whole batch — normally one fused
//!    [`Oracle::loss_k`] dispatch, or K separate `loss_dir` calls for
//!    per-probe A/B benchmarking (`ProbeDispatch` in [`crate::train`]);
//! 3. [`GradEstimator::consume`] combines the probe losses into `g` with
//!    the blocked [`probe_combine`] kernel (plus at most one follow-up
//!    point evaluation: the forward-difference base loss, or Algorithm 2's
//!    central-difference probe at `-tau` along the selected direction).
//!
//! [`GradEstimator::estimate`] is the one-call convenience that wires the
//! three steps together; sharding or multi-backend dispatch can instead
//! split the phases and route the probe matrix wherever it likes.

use anyhow::{bail, Result};

use crate::oracle::Oracle;
use crate::sampler::DirectionSampler;
use crate::tensor::{axpy, probe_combine};

/// One batch of probe evaluations requested by [`GradEstimator::propose`]:
/// `k` rows of a row-major `k x d` direction matrix, each to be evaluated
/// at `f(x + tau * dir)`.
#[derive(Clone, Copy, Debug)]
pub struct ProbeBatch<'a> {
    /// Row-major `k x d` direction matrix (borrowed from the estimator's
    /// reusable buffer; valid until the next `propose`).
    pub dirs: &'a [f32],
    /// Number of probe rows.
    pub k: usize,
    /// Finite-difference scale each row is evaluated at.
    pub tau: f32,
}

/// Outcome of one estimation step.
#[derive(Clone, Debug)]
pub struct Estimate {
    /// Oracle calls consumed by this step.
    pub calls: u64,
    /// Probe losses observed (diagnostics).
    pub losses: Vec<f64>,
    /// Index of the selected direction (Algorithm 2 line 4), if any.
    pub selected: Option<usize>,
    /// The finite-difference coefficient applied to the selected direction
    /// (0 when `g` is an average).
    pub fd_coeff: f64,
}

/// Turns forward evaluations into a dense gradient surrogate.
pub trait GradEstimator {
    /// Phase 1: sample this step's directions into the estimator's
    /// reusable probe matrix and describe the required evaluations.
    /// Performs no oracle calls.
    fn propose(&mut self) -> Result<ProbeBatch<'_>>;

    /// Phase 2: combine the `losses` of the last proposed batch (in row
    /// order) into `g` (len d).  May spend extra oracle calls for point
    /// evaluations that cannot be batched (see the module docs); the
    /// returned [`Estimate::calls`] covers the whole step including the
    /// batch itself.
    ///
    /// Each `consume` must be paired with a preceding call to
    /// [`GradEstimator::propose`]: combining without one (or twice for
    /// one propose) would silently read a stale or zero probe matrix,
    /// so it is an error.
    fn consume(
        &mut self,
        oracle: &mut dyn Oracle,
        losses: &[f64],
        g: &mut [f32],
    ) -> Result<Estimate>;

    /// Estimate grad f(x) into `g` (len d) in one call: propose, evaluate
    /// the batch via one fused [`Oracle::loss_k`] dispatch, consume.  The
    /// oracle's current batch must be set by the caller.
    fn estimate(&mut self, oracle: &mut dyn Oracle, g: &mut [f32]) -> Result<Estimate> {
        let losses = {
            let batch = self.propose()?;
            oracle.loss_k(batch.dirs, batch.k, batch.tau)?
        };
        self.consume(oracle, &losses, g)
    }

    /// Oracle calls one step consumes (for budget planning).
    fn calls_per_step(&self) -> u64;

    /// Short identifier used in run labels.
    fn name(&self) -> &str;

    /// Bytes of persistent estimator state (memory accounting): direction
    /// buffers + sampler policy state.
    fn state_bytes(&self) -> usize;
}

/// Classical ZO central difference with a single probe direction
/// (MeZO-style; the "Gaussian, 2 forwards, more iterations" row of
/// Table 1):  g = v * (f(x + tau v) - f(x - tau v)) / (2 tau).
///
/// Batched form: the probe matrix is `[v; -v]` (2 x d), so both sides of
/// the central difference ride one `loss_k` dispatch.
pub struct CentralK1Estimator<S: DirectionSampler> {
    /// Direction source for the single probe v.
    pub sampler: S,
    /// Finite-difference scale.
    pub tau: f32,
    /// 2 x d probe matrix: row 0 is v, row 1 is -v.
    dirs: Vec<f32>,
    proposed: bool,
}

impl<S: DirectionSampler> CentralK1Estimator<S> {
    /// Build with a direction sampler and finite-difference scale.
    pub fn new(sampler: S, tau: f32) -> Self {
        let d = sampler.dim();
        Self { sampler, tau, dirs: vec![0.0; 2 * d], proposed: false }
    }
}

impl<S: DirectionSampler> GradEstimator for CentralK1Estimator<S> {
    fn propose(&mut self) -> Result<ProbeBatch<'_>> {
        let d = self.sampler.dim();
        let (v, neg) = self.dirs.split_at_mut(d);
        self.sampler.sample(v, 1);
        for (n, x) in neg.iter_mut().zip(v.iter()) {
            *n = -*x;
        }
        self.proposed = true;
        Ok(ProbeBatch { dirs: &self.dirs, k: 2, tau: self.tau })
    }

    fn consume(
        &mut self,
        _oracle: &mut dyn Oracle,
        losses: &[f64],
        g: &mut [f32],
    ) -> Result<Estimate> {
        if !self.proposed {
            bail!("central_k1: consume without a matching propose");
        }
        if losses.len() != 2 {
            bail!("central_k1: expected 2 probe losses, got {}", losses.len());
        }
        self.proposed = false;
        let d = self.sampler.dim();
        let (fp, fm) = (losses[0], losses[1]);
        let coeff = (fp - fm) / (2.0 * self.tau as f64);
        g.iter_mut().for_each(|v| *v = 0.0);
        axpy(coeff as f32, &self.dirs[..d], g);
        Ok(Estimate { calls: 2, losses: vec![fp, fm], selected: Some(0), fd_coeff: coeff })
    }

    fn calls_per_step(&self) -> u64 {
        2
    }

    fn name(&self) -> &str {
        "central_k1"
    }

    fn state_bytes(&self) -> usize {
        self.dirs.len() * 4 + self.sampler.state_bytes()
    }
}

/// Monte-Carlo forward-difference averaging (eq. 5 with one-point probes;
/// the "Gaussian, 6 forwards, same iterations" row):
/// g = (1/K) sum_i v_i (f(x + tau v_i) - f(x)) / tau.
///
/// Batched form: all K probes go through one `loss_k` dispatch; the base
/// loss f(x) is the one point evaluation `consume` performs, and the
/// combine is a single [`probe_combine`] reduce over the probe matrix.
pub struct ForwardAvgEstimator<S: DirectionSampler> {
    /// Direction source for the K probes.
    pub sampler: S,
    /// Finite-difference scale.
    pub tau: f32,
    /// Number of probe directions per step.
    pub k: usize,
    dirs: Vec<f32>,
    weights: Vec<f32>,
    zero: Vec<f32>,
    proposed: bool,
}

impl<S: DirectionSampler> ForwardAvgEstimator<S> {
    /// Build with a direction sampler, finite-difference scale and probe
    /// count (k >= 1).
    pub fn new(sampler: S, tau: f32, k: usize) -> Self {
        assert!(k >= 1);
        let d = sampler.dim();
        Self {
            sampler,
            tau,
            k,
            dirs: vec![0.0; k * d],
            weights: Vec::with_capacity(k),
            zero: vec![0.0; d],
            proposed: false,
        }
    }
}

impl<S: DirectionSampler> GradEstimator for ForwardAvgEstimator<S> {
    fn propose(&mut self) -> Result<ProbeBatch<'_>> {
        self.sampler.sample(&mut self.dirs, self.k);
        self.proposed = true;
        Ok(ProbeBatch { dirs: &self.dirs, k: self.k, tau: self.tau })
    }

    fn consume(
        &mut self,
        oracle: &mut dyn Oracle,
        losses: &[f64],
        g: &mut [f32],
    ) -> Result<Estimate> {
        if !self.proposed {
            bail!("forward_avg: consume without a matching propose");
        }
        if losses.len() != self.k {
            bail!(
                "forward_avg: expected {} probe losses, got {}",
                self.k,
                losses.len()
            );
        }
        self.proposed = false;
        let d = self.sampler.dim();
        let f_base = oracle.loss_dir(&self.zero, 0.0)?;
        let denom = self.k as f64 * self.tau as f64;
        self.weights.clear();
        self.weights
            .extend(losses.iter().map(|l| ((l - f_base) / denom) as f32));
        probe_combine(&self.dirs, d, &self.weights, g);
        let mut all = vec![f_base];
        all.extend_from_slice(losses);
        Ok(Estimate {
            calls: self.k as u64 + 1,
            losses: all,
            selected: None,
            fd_coeff: 0.0,
        })
    }

    fn calls_per_step(&self) -> u64 {
        self.k as u64 + 1
    }

    fn name(&self) -> &str {
        "forward_avg"
    }

    fn state_bytes(&self) -> usize {
        (self.dirs.len() + self.weights.capacity() + self.zero.len()) * 4
            + self.sampler.state_bytes()
    }
}

/// Algorithm 2 (ZO-LDSD): sample K candidates from the (learnable) policy,
/// greedily select the probe with the lowest loss, take a central
/// difference along it, and update the policy from all K probe losses.
///
/// Works with *any* [`DirectionSampler`]; with `GaussianSampler` it
/// degenerates to best-of-K Gaussian selection (an ablation arm), with
/// [`crate::sampler::LdsdSampler`] it is the paper's full method.
///
/// Batched form: the K candidate probes ride one `loss_k` dispatch;
/// `consume` spends one extra `loss_dir` at `-tau` along the selected
/// direction (line 5 reuses the `+tau` loss from the batch), then feeds
/// the *same* probe matrix to the sampler's REINFORCE update — no second
/// pass over K vectors.
pub struct LdsdEstimator<S: DirectionSampler> {
    /// Direction policy (learnable for [`crate::sampler::LdsdSampler`]).
    pub sampler: S,
    /// Finite-difference scale.
    pub tau: f32,
    /// Number of candidate directions per step.
    pub k: usize,
    dirs: Vec<f32>,
    proposed: bool,
}

impl<S: DirectionSampler> LdsdEstimator<S> {
    /// Build with a direction sampler, finite-difference scale and
    /// candidate count (k >= 1).
    pub fn new(sampler: S, tau: f32, k: usize) -> Self {
        assert!(k >= 1);
        let d = sampler.dim();
        Self { sampler, tau, k, dirs: vec![0.0; k * d], proposed: false }
    }

    /// The underlying direction sampler (policy diagnostics).
    pub fn sampler(&self) -> &S {
        &self.sampler
    }
}

impl<S: DirectionSampler> GradEstimator for LdsdEstimator<S> {
    fn propose(&mut self) -> Result<ProbeBatch<'_>> {
        self.sampler.sample(&mut self.dirs, self.k);
        self.proposed = true;
        Ok(ProbeBatch { dirs: &self.dirs, k: self.k, tau: self.tau })
    }

    fn consume(
        &mut self,
        oracle: &mut dyn Oracle,
        losses: &[f64],
        g: &mut [f32],
    ) -> Result<Estimate> {
        if !self.proposed {
            bail!("ldsd_bestofk: consume without a matching propose");
        }
        if losses.len() != self.k {
            bail!(
                "ldsd_bestofk: expected {} probe losses, got {}",
                self.k,
                losses.len()
            );
        }
        self.proposed = false;
        let d = self.sampler.dim();
        // greedy selection (line 4)
        let best = losses
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
            .map(|(i, _)| i)
            .unwrap_or(0);
        let vstar = &self.dirs[best * d..(best + 1) * d];
        // central difference along v* (line 5); f(x + tau v*) is reused
        let f_minus = oracle.loss_dir(vstar, -self.tau)?;
        let coeff = (losses[best] - f_minus) / (2.0 * self.tau as f64);
        g.iter_mut().for_each(|v| *v = 0.0);
        axpy(coeff as f32, vstar, g);
        // policy update from all K probes (lines 6/8), reusing the probe
        // matrix the batch was evaluated on
        self.sampler.observe(&self.dirs, losses, self.k);
        let mut all = losses.to_vec();
        all.push(f_minus);
        Ok(Estimate {
            calls: self.k as u64 + 1,
            losses: all,
            selected: Some(best),
            fd_coeff: coeff,
        })
    }

    fn calls_per_step(&self) -> u64 {
        self.k as u64 + 1
    }

    fn name(&self) -> &str {
        "ldsd_bestofk"
    }

    fn state_bytes(&self) -> usize {
        self.dirs.len() * 4 + self.sampler.state_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::oracle::QuadraticOracle;
    use crate::sampler::{GaussianSampler, LdsdConfig, LdsdSampler};
    use crate::tensor::cosine;

    fn quad(d: usize) -> QuadraticOracle {
        // f(x) = 0.5 ||x - 1||^2 from x = 0: grad = x - 1 = -1
        QuadraticOracle::new(vec![1.0; d], vec![1.0; d], vec![0.0; d])
    }

    #[test]
    fn central_k1_matches_directional_derivative() {
        let d = 24;
        let mut o = quad(d);
        let mut est = CentralK1Estimator::new(GaussianSampler::new(d, 1), 1e-3);
        let mut g = vec![0.0f32; d];
        let e = est.estimate(&mut o, &mut g).unwrap();
        assert_eq!(e.calls, 2);
        // for the quadratic, fd along v is exact: coeff = <grad, v>
        // (est.dirs row 0 is v; zip stops at d)
        let true_grad = vec![-1.0f32; d];
        let vdotg: f32 = true_grad
            .iter()
            .zip(est.dirs.iter())
            .map(|(a, b)| a * b)
            .sum();
        assert!(
            ((e.fd_coeff as f32) - vdotg).abs() < 1e-2 * (1.0 + vdotg.abs()),
            "coeff {} vs <g,v> {vdotg}",
            e.fd_coeff
        );
    }

    #[test]
    fn central_k1_probe_matrix_is_plus_minus_v() {
        let d = 8;
        let mut est = CentralK1Estimator::new(GaussianSampler::new(d, 3), 1e-3);
        let batch = est.propose().unwrap();
        assert_eq!(batch.k, 2);
        assert_eq!(batch.dirs.len(), 2 * d);
        for i in 0..d {
            assert_eq!(batch.dirs[d + i], -batch.dirs[i]);
        }
    }

    #[test]
    fn forward_avg_unbiasedish_over_many_steps() {
        let d = 8;
        let mut o = quad(d);
        let mut est = ForwardAvgEstimator::new(GaussianSampler::new(d, 2), 1e-3, 4);
        let mut g = vec![0.0f32; d];
        let mut acc = vec![0.0f32; d];
        let reps = 400;
        for _ in 0..reps {
            est.estimate(&mut o, &mut g).unwrap();
            axpy(1.0 / reps as f32, &g, &mut acc);
        }
        let true_grad = vec![-1.0f32; d];
        let cos = cosine(&acc, &true_grad);
        assert!(cos > 0.9, "averaged estimate should align with grad, cos={cos}");
    }

    #[test]
    fn propose_consume_split_matches_estimate() {
        // Driving the two phases by hand (per-probe loss_dir dispatch)
        // must produce the same estimate as the fused path with the same
        // sampler stream.
        let d = 16;
        let k = 5;
        let mut o1 = quad(d);
        let mut fused = LdsdEstimator::new(
            LdsdSampler::new(d, 11, LdsdConfig::default()),
            1e-3,
            k,
        );
        let mut g1 = vec![0.0f32; d];
        let e1 = fused.estimate(&mut o1, &mut g1).unwrap();

        let mut o2 = quad(d);
        let mut split = LdsdEstimator::new(
            LdsdSampler::new(d, 11, LdsdConfig::default()),
            1e-3,
            k,
        );
        let mut g2 = vec![0.0f32; d];
        let losses = {
            let batch = split.propose().unwrap();
            (0..batch.k)
                .map(|i| {
                    o2.loss_dir(&batch.dirs[i * d..(i + 1) * d], batch.tau)
                        .unwrap()
                })
                .collect::<Vec<f64>>()
        };
        let e2 = split.consume(&mut o2, &losses, &mut g2).unwrap();

        assert_eq!(e1.selected, e2.selected);
        assert_eq!(e1.calls, e2.calls);
        assert_eq!(o1.oracle_calls(), o2.oracle_calls());
        for (a, b) in g1.iter().zip(g2.iter()) {
            assert!((a - b).abs() <= 1e-5 * (1.0 + a.abs()), "{a} vs {b}");
        }
    }

    #[test]
    fn consume_rejects_wrong_loss_count() {
        let d = 8;
        let mut o = quad(d);
        let mut est = LdsdEstimator::new(
            LdsdSampler::new(d, 1, LdsdConfig::default()),
            1e-3,
            3,
        );
        let mut g = vec![0.0f32; d];
        let _ = est.propose().unwrap();
        assert!(est.consume(&mut o, &[0.1, 0.2], &mut g).is_err());
    }

    #[test]
    fn consume_requires_propose() {
        // Combining without a propose (or twice per propose) would read a
        // stale/zero probe matrix; both must be rejected.
        let d = 8;
        let mut o = quad(d);
        let mut est = LdsdEstimator::new(
            LdsdSampler::new(d, 1, LdsdConfig::default()),
            1e-3,
            3,
        );
        let mut g = vec![0.0f32; d];
        let losses = [0.1f64, 0.2, 0.3];
        assert!(est.consume(&mut o, &losses, &mut g).is_err());
        let _ = est.propose().unwrap();
        assert!(est.consume(&mut o, &losses, &mut g).is_ok());
        assert!(
            est.consume(&mut o, &losses, &mut g).is_err(),
            "second consume for one propose must be rejected"
        );
    }

    #[test]
    fn ldsd_selects_lowest_probe() {
        let d = 16;
        let mut o = quad(d);
        let sampler = LdsdSampler::new(d, 3, LdsdConfig::default());
        let mut est = LdsdEstimator::new(sampler, 1e-3, 5);
        let mut g = vec![0.0f32; d];
        let e = est.estimate(&mut o, &mut g).unwrap();
        assert_eq!(e.calls, 6);
        let probes = &e.losses[..5];
        let best = e.selected.unwrap();
        for p in probes {
            assert!(probes[best] <= *p);
        }
    }

    #[test]
    fn ldsd_gradient_points_downhill() {
        // A step along -g must not increase the quadratic's loss (descent
        // direction on average); check over several steps.
        let d = 32;
        let mut o = quad(d);
        let sampler = LdsdSampler::new(d, 5, LdsdConfig::default());
        let mut est = LdsdEstimator::new(sampler, 1e-3, 5);
        let mut g = vec![0.0f32; d];
        let mut downhill = 0;
        let reps = 30;
        for _ in 0..reps {
            est.estimate(&mut o, &mut g).unwrap();
            let zero = vec![0.0f32; d];
            let f0 = o.loss_dir(&zero, 0.0).unwrap();
            let f1 = o.loss_dir(&g, -1e-2).unwrap();
            if f1 <= f0 {
                downhill += 1;
            }
        }
        assert!(downhill >= reps * 2 / 3, "downhill {downhill}/{reps}");
    }

    #[test]
    fn budget_accounting_exact() {
        let d = 8;
        let mut o = quad(d);
        let mut est = LdsdEstimator::new(
            LdsdSampler::new(d, 1, LdsdConfig::default()),
            1e-3,
            3,
        );
        let mut g = vec![0.0f32; d];
        let before = o.oracle_calls();
        let e = est.estimate(&mut o, &mut g).unwrap();
        assert_eq!(o.oracle_calls() - before, e.calls);
        assert_eq!(e.calls, est.calls_per_step());
    }
}
