//! Gradient estimators: forward evaluations -> gradient surrogate.
//!
//! All estimators write a dense `g` into a caller-provided buffer so the
//! base optimizers are strategy-agnostic (the paper's plug-in claim), and
//! report exactly how many oracle calls they spent (the §5.1 budget-fair
//! protocol charges estimators by calls, not iterations).

use anyhow::Result;

use crate::oracle::Oracle;
use crate::sampler::DirectionSampler;
use crate::tensor::{axpy, scal};

/// Outcome of one estimation step.
#[derive(Clone, Debug)]
pub struct Estimate {
    /// Oracle calls consumed by this step.
    pub calls: u64,
    /// Probe losses observed (diagnostics).
    pub losses: Vec<f64>,
    /// Index of the selected direction (Algorithm 2 line 4), if any.
    pub selected: Option<usize>,
    /// The finite-difference coefficient applied to the selected direction
    /// (0 when `g` is an average).
    pub fd_coeff: f64,
}

pub trait GradEstimator {
    /// Estimate grad f(x) into `g` (len d).  The oracle's current batch
    /// must be set by the caller.
    fn estimate(&mut self, oracle: &mut dyn Oracle, g: &mut [f32]) -> Result<Estimate>;

    /// Oracle calls one step consumes (for budget planning).
    fn calls_per_step(&self) -> u64;

    fn name(&self) -> &str;

    /// Bytes of persistent estimator state (memory accounting): direction
    /// buffers + sampler policy state.
    fn state_bytes(&self) -> usize;
}

/// Classical ZO central difference with a single probe direction
/// (MeZO-style; the "Gaussian, 2 forwards, more iterations" row of
/// Table 1):  g = v * (f(x + tau v) - f(x - tau v)) / (2 tau).
pub struct CentralK1Estimator<S: DirectionSampler> {
    pub sampler: S,
    pub tau: f32,
    dir: Vec<f32>,
}

impl<S: DirectionSampler> CentralK1Estimator<S> {
    pub fn new(sampler: S, tau: f32) -> Self {
        let d = sampler.dim();
        Self { sampler, tau, dir: vec![0.0; d] }
    }
}

impl<S: DirectionSampler> GradEstimator for CentralK1Estimator<S> {
    fn estimate(&mut self, oracle: &mut dyn Oracle, g: &mut [f32]) -> Result<Estimate> {
        self.sampler.sample(&mut self.dir, 1);
        let fp = oracle.loss_dir(&self.dir, self.tau)?;
        let fm = oracle.loss_dir(&self.dir, -self.tau)?;
        let coeff = (fp - fm) / (2.0 * self.tau as f64);
        g.iter_mut().for_each(|v| *v = 0.0);
        axpy(coeff as f32, &self.dir, g);
        Ok(Estimate { calls: 2, losses: vec![fp, fm], selected: Some(0), fd_coeff: coeff })
    }

    fn calls_per_step(&self) -> u64 {
        2
    }

    fn name(&self) -> &str {
        "central_k1"
    }

    fn state_bytes(&self) -> usize {
        self.dir.len() * 4 + self.sampler.state_bytes()
    }
}

/// Monte-Carlo forward-difference averaging (eq. 5 with one-point probes;
/// the "Gaussian, 6 forwards, same iterations" row):
/// g = (1/K) sum_i v_i (f(x + tau v_i) - f(x)) / tau.
pub struct ForwardAvgEstimator<S: DirectionSampler> {
    pub sampler: S,
    pub tau: f32,
    pub k: usize,
    dirs: Vec<f32>,
    zero: Vec<f32>,
}

impl<S: DirectionSampler> ForwardAvgEstimator<S> {
    pub fn new(sampler: S, tau: f32, k: usize) -> Self {
        assert!(k >= 1);
        let d = sampler.dim();
        Self { sampler, tau, k, dirs: vec![0.0; k * d], zero: vec![0.0; d] }
    }
}

impl<S: DirectionSampler> GradEstimator for ForwardAvgEstimator<S> {
    fn estimate(&mut self, oracle: &mut dyn Oracle, g: &mut [f32]) -> Result<Estimate> {
        let d = oracle.dim();
        self.sampler.sample(&mut self.dirs, self.k);
        let f_base = oracle.loss_dir(&self.zero, 0.0)?;
        let losses = oracle.loss_k(&self.dirs, self.k, self.tau)?;
        g.iter_mut().for_each(|v| *v = 0.0);
        for i in 0..self.k {
            let coeff = (losses[i] - f_base) / self.tau as f64;
            axpy(coeff as f32, &self.dirs[i * d..(i + 1) * d], g);
        }
        scal(1.0 / self.k as f32, g);
        let mut all = vec![f_base];
        all.extend_from_slice(&losses);
        Ok(Estimate {
            calls: self.k as u64 + 1,
            losses: all,
            selected: None,
            fd_coeff: 0.0,
        })
    }

    fn calls_per_step(&self) -> u64 {
        self.k as u64 + 1
    }

    fn name(&self) -> &str {
        "forward_avg"
    }

    fn state_bytes(&self) -> usize {
        self.dirs.len() * 4 + self.sampler.state_bytes()
    }
}

/// Algorithm 2 (ZO-LDSD): sample K candidates from the (learnable) policy,
/// greedily select the probe with the lowest loss, take a central
/// difference along it, and update the policy from all K probe losses.
///
/// Works with *any* [`DirectionSampler`]; with `GaussianSampler` it
/// degenerates to best-of-K Gaussian selection (an ablation arm), with
/// [`crate::sampler::LdsdSampler`] it is the paper's full method.
pub struct LdsdEstimator<S: DirectionSampler> {
    pub sampler: S,
    pub tau: f32,
    pub k: usize,
    dirs: Vec<f32>,
}

impl<S: DirectionSampler> LdsdEstimator<S> {
    pub fn new(sampler: S, tau: f32, k: usize) -> Self {
        assert!(k >= 1);
        let d = sampler.dim();
        Self { sampler, tau, k, dirs: vec![0.0; k * d] }
    }

    pub fn sampler(&self) -> &S {
        &self.sampler
    }
}

impl<S: DirectionSampler> GradEstimator for LdsdEstimator<S> {
    fn estimate(&mut self, oracle: &mut dyn Oracle, g: &mut [f32]) -> Result<Estimate> {
        let d = oracle.dim();
        self.sampler.sample(&mut self.dirs, self.k);
        // K probes at +tau (one fused dispatch on the PJRT oracle)
        let losses = oracle.loss_k(&self.dirs, self.k, self.tau)?;
        // greedy selection (line 4)
        let best = losses
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
            .map(|(i, _)| i)
            .unwrap_or(0);
        let vstar = &self.dirs[best * d..(best + 1) * d];
        // central difference along v* (line 5); f(x + tau v*) is reused
        let f_minus = oracle.loss_dir(vstar, -self.tau)?;
        let coeff = (losses[best] - f_minus) / (2.0 * self.tau as f64);
        g.iter_mut().for_each(|v| *v = 0.0);
        axpy(coeff as f32, vstar, g);
        // policy update from all K probes (lines 6/8)
        self.sampler.observe(&self.dirs, &losses, self.k);
        let mut all = losses;
        all.push(f_minus);
        Ok(Estimate {
            calls: self.k as u64 + 1,
            losses: all,
            selected: Some(best),
            fd_coeff: coeff,
        })
    }

    fn calls_per_step(&self) -> u64 {
        self.k as u64 + 1
    }

    fn name(&self) -> &str {
        "ldsd_bestofk"
    }

    fn state_bytes(&self) -> usize {
        self.dirs.len() * 4 + self.sampler.state_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::oracle::QuadraticOracle;
    use crate::sampler::{GaussianSampler, LdsdConfig, LdsdSampler};
    use crate::tensor::cosine;

    fn quad(d: usize) -> QuadraticOracle {
        // f(x) = 0.5 ||x - 1||^2 from x = 0: grad = x - 1 = -1
        QuadraticOracle::new(vec![1.0; d], vec![1.0; d], vec![0.0; d])
    }

    #[test]
    fn central_k1_matches_directional_derivative() {
        let d = 24;
        let mut o = quad(d);
        let mut est = CentralK1Estimator::new(GaussianSampler::new(d, 1), 1e-3);
        let mut g = vec![0.0f32; d];
        let e = est.estimate(&mut o, &mut g).unwrap();
        assert_eq!(e.calls, 2);
        // for the quadratic, fd along v is exact: coeff = <grad, v>
        let true_grad = vec![-1.0f32; d];
        let vdotg: f32 = true_grad
            .iter()
            .zip(est.dir.iter())
            .map(|(a, b)| a * b)
            .sum();
        assert!(
            ((e.fd_coeff as f32) - vdotg).abs() < 1e-2 * (1.0 + vdotg.abs()),
            "coeff {} vs <g,v> {vdotg}",
            e.fd_coeff
        );
    }

    #[test]
    fn forward_avg_unbiasedish_over_many_steps() {
        let d = 8;
        let mut o = quad(d);
        let mut est = ForwardAvgEstimator::new(GaussianSampler::new(d, 2), 1e-3, 4);
        let mut g = vec![0.0f32; d];
        let mut acc = vec![0.0f32; d];
        let reps = 400;
        for _ in 0..reps {
            est.estimate(&mut o, &mut g).unwrap();
            axpy(1.0 / reps as f32, &g, &mut acc);
        }
        let true_grad = vec![-1.0f32; d];
        let cos = cosine(&acc, &true_grad);
        assert!(cos > 0.9, "averaged estimate should align with grad, cos={cos}");
    }

    #[test]
    fn ldsd_selects_lowest_probe() {
        let d = 16;
        let mut o = quad(d);
        let sampler = LdsdSampler::new(d, 3, LdsdConfig::default());
        let mut est = LdsdEstimator::new(sampler, 1e-3, 5);
        let mut g = vec![0.0f32; d];
        let e = est.estimate(&mut o, &mut g).unwrap();
        assert_eq!(e.calls, 6);
        let probes = &e.losses[..5];
        let best = e.selected.unwrap();
        for p in probes {
            assert!(probes[best] <= *p);
        }
    }

    #[test]
    fn ldsd_gradient_points_downhill() {
        // A step along -g must not increase the quadratic's loss (descent
        // direction on average); check over several steps.
        let d = 32;
        let mut o = quad(d);
        let sampler = LdsdSampler::new(d, 5, LdsdConfig::default());
        let mut est = LdsdEstimator::new(sampler, 1e-3, 5);
        let mut g = vec![0.0f32; d];
        let mut downhill = 0;
        let reps = 30;
        for _ in 0..reps {
            est.estimate(&mut o, &mut g).unwrap();
            let zero = vec![0.0f32; d];
            let f0 = o.loss_dir(&zero, 0.0).unwrap();
            let f1 = o.loss_dir(&g, -1e-2).unwrap();
            if f1 <= f0 {
                downhill += 1;
            }
        }
        assert!(downhill >= reps * 2 / 3, "downhill {downhill}/{reps}");
    }

    #[test]
    fn budget_accounting_exact() {
        let d = 8;
        let mut o = quad(d);
        let mut est = LdsdEstimator::new(
            LdsdSampler::new(d, 1, LdsdConfig::default()),
            1e-3,
            3,
        );
        let mut g = vec![0.0f32; d];
        let before = o.oracle_calls();
        let e = est.estimate(&mut o, &mut g).unwrap();
        assert_eq!(o.oracle_calls() - before, e.calls);
        assert_eq!(e.calls, est.calls_per_step());
    }
}
