//! First-order reference optimizers (memory-comparison table + pretrain
//! parity checks).  These are *not* part of the ZO pipeline; they exist so
//! the memory_table bench can report optimizer-state footprints of the
//! backprop pipeline the paper compares against (§1).

use super::optimizers::{BaseOptimizer, OptimizerState};

/// Plain first-order SGD (momentum optional) — identical math to ZoSgd but
/// kept as a distinct type so the memory table can label FO vs ZO rows.
pub struct FoSgd(
    /// The shared update rule.
    pub super::ZoSgd,
);

impl FoSgd {
    /// Build for dimensionality `d` with heavy-ball `momentum`.
    pub fn new(d: usize, momentum: f32) -> Self {
        Self(super::ZoSgd::new(d, momentum))
    }
}

impl BaseOptimizer for FoSgd {
    fn step(&mut self, params: &mut [f32], g: &[f32], lr: f32) {
        self.0.step(params, g, lr);
    }

    fn state_bytes(&self) -> usize {
        self.0.state_bytes()
    }

    fn state(&self) -> OptimizerState {
        self.0.state()
    }

    fn load_state(&mut self, state: &OptimizerState) -> anyhow::Result<()> {
        self.0.load_state(state)
    }

    fn name(&self) -> &str {
        "fo_sgd"
    }
}

/// First-order Adam.
pub struct FoAdam(
    /// The shared update rule.
    pub super::ZoAdaMM,
);

impl FoAdam {
    /// Build for dimensionality `d` with standard betas (0.9, 0.999).
    pub fn new(d: usize) -> Self {
        Self(super::ZoAdaMM::new(d, 0.9, 0.999))
    }
}

impl BaseOptimizer for FoAdam {
    fn step(&mut self, params: &mut [f32], g: &[f32], lr: f32) {
        self.0.step(params, g, lr);
    }

    fn state_bytes(&self) -> usize {
        self.0.state_bytes()
    }

    fn state(&self) -> OptimizerState {
        self.0.state()
    }

    fn load_state(&mut self, state: &OptimizerState) -> anyhow::Result<()> {
        self.0.load_state(state)
    }

    fn name(&self) -> &str {
        "fo_adam"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fo_adam_state_is_2d_floats() {
        let opt = FoAdam::new(100);
        assert_eq!(opt.state_bytes(), 800);
    }

    #[test]
    fn fo_sgd_converges() {
        let mut opt = FoSgd::new(4, 0.9);
        let mut x = vec![1.0f32; 4];
        let mut g = vec![0.0f32; 4];
        for _ in 0..500 {
            g.copy_from_slice(&x);
            opt.step(&mut x, &g, 0.05);
        }
        assert!(x.iter().all(|v| v.abs() < 1e-2));
    }
}
