//! ZO optimization: gradient estimators x base optimizers.
//!
//! The paper's §4 modularity maps to two orthogonal traits:
//! * [`GradEstimator`] — turns forward evaluations into a gradient
//!   surrogate `g` (this is where sampling strategy + probe layout live:
//!   central-difference K=1, forward-difference MC averaging, or the
//!   paper's Algorithm 2 best-of-K with policy learning).
//! * [`BaseOptimizer`] — consumes `g` exactly like a first-order method
//!   (ZO-SGD momentum, ZO-AdaMM, JAGUAR SignSGD...).  Base optimizer
//!   hyperparameters never change when the estimator is swapped — that is
//!   the paper's controlled-comparison protocol (§5.1).
//!
//! `dgd.rs` holds the first-order directional-descent instantiation
//! (Algorithm 1) used by the Fig. 2 toy experiment.

pub mod dgd;
mod estimator;
mod first_order;
mod mezo;
mod optimizers;

pub use dgd::{DgdConfig, DgdRunner, DgdVariant};
pub use estimator::{
    CentralK1Estimator, Estimate, ForwardAvgEstimator, GradEstimator,
    LdsdEstimator, ProbeBatch,
};
pub use first_order::{FoAdam, FoSgd};
pub use mezo::{MezoSgd, MezoStepInfo};
pub use optimizers::{
    by_name as optimizers_by_name, BaseOptimizer, JaguarSignSgd, OptimizerState,
    ZoAdaMM, ZoSgd,
};
