//! Directional Gradient Descent (Algorithm 1) — the first-order
//! instantiation used by the paper's toy experiment (§3.6, Fig. 2).
//!
//! The oracle exposes the true gradient; the *estimator* only sees it
//! through directional projections (eq. 3/5):
//!
//! ```text
//! g_x = (1/K) sum_k  v̄_k <v̄_k, grad f(x)>
//! ```
//!
//! with v_k ~ N(0, I) for the baseline and v_k ~ N(mu, eps^2 I) for LDSD,
//! whose mu follows the §3.6 REINFORCE ascent on the alignment reward
//! C_k = <v̄_k, grad-f-bar>^2 with a mean baseline.

use anyhow::Result;

use crate::oracle::GradOracle;
use crate::rng::Rng;
use crate::sampler::AlignmentTracker;
use crate::tensor::{axpy, cosine, dot, normalize, nrm2, scal};

/// Which direction distribution feeds Algorithm 1.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DgdVariant {
    /// v ~ N(0, I), no policy (the paper's baseline, gamma_x = 200).
    Baseline,
    /// v ~ N(mu, eps^2 I) with the learnable mean (gamma_x = 5,
    /// gamma_mu = 1.4e-5, eps = 1.2e-2 per §A.1).
    Ldsd,
}

/// Hyperparameters of the Fig. 2 DGD run.
#[derive(Clone, Debug)]
pub struct DgdConfig {
    /// Baseline (Gaussian) or LDSD (learnable-mean) sampling.
    pub variant: DgdVariant,
    /// Directions per step.
    pub k: usize,
    /// x-step size.
    pub gamma_x: f32,
    /// Policy-mean step size (LDSD only).
    pub gamma_mu: f32,
    /// Sampling std-dev around mu (LDSD only).
    pub eps: f32,
    /// Iterations to run.
    pub steps: usize,
    /// RNG seed.
    pub seed: u64,
    /// ||mu^0|| for the LDSD variant (random direction at this norm).
    pub mu_init_norm: f32,
}

impl DgdConfig {
    /// Paper §A.1 baseline hyperparameters.
    pub fn paper_baseline(steps: usize, seed: u64) -> Self {
        Self {
            variant: DgdVariant::Baseline,
            k: 5,
            gamma_x: 200.0,
            gamma_mu: 0.0,
            eps: 1.0,
            steps,
            seed,
            mu_init_norm: 1.0,
        }
    }

    /// Paper §A.1 LDSD hyperparameters.
    pub fn paper_ldsd(steps: usize, seed: u64) -> Self {
        Self {
            variant: DgdVariant::Ldsd,
            k: 5,
            gamma_x: 5.0,
            gamma_mu: 1.4e-5,
            eps: 1.2e-2,
            steps,
            seed,
            mu_init_norm: 1.0,
        }
    }
}

/// Per-iteration series recorded for Fig. 2.
#[derive(Clone, Debug, Default)]
pub struct DgdTrace {
    /// cos(g_x, grad f) per step — Fig. 2 left panel.
    pub alignment: Vec<f32>,
    /// ||grad f(x)|| per step — Fig. 2 right panel.
    pub grad_norm: Vec<f32>,
    /// f(x) per step.
    pub loss: Vec<f64>,
    /// cos(mu, grad f) per step (LDSD only; policy diagnostics).
    pub mu_alignment: Vec<f32>,
}

/// Runs Algorithm 1 against a [`GradOracle`] and records the Fig. 2 series.
pub struct DgdRunner {
    /// The run configuration.
    pub cfg: DgdConfig,
    rng: Rng,
    mu: Vec<f32>,
}

impl DgdRunner {
    /// Initialize for dimensionality `d` (random mu at `mu_init_norm`).
    pub fn new(cfg: DgdConfig, d: usize) -> Self {
        let mut rng = Rng::new(cfg.seed);
        let mut mu = vec![0.0f32; d];
        if cfg.variant == DgdVariant::Ldsd {
            rng.fill_normal(&mut mu);
            let n = nrm2(&mu);
            if n > 0.0 {
                scal(cfg.mu_init_norm / n, &mut mu);
            }
        }
        Self { cfg, rng, mu }
    }

    /// Warm-start mu along a direction (Lemma 3 initialization).
    pub fn set_mu(&mut self, dir: &[f32]) {
        assert_eq!(dir.len(), self.mu.len());
        self.mu.copy_from_slice(dir);
        let n = nrm2(&self.mu);
        if n > 0.0 {
            scal(self.cfg.mu_init_norm / n, &mut self.mu);
        }
    }

    /// The current policy mean.
    pub fn mu(&self) -> &[f32] {
        &self.mu
    }

    /// Run Algorithm 1 against a first-order oracle; returns the Fig. 2
    /// series.
    pub fn run<O: GradOracle>(&mut self, oracle: &mut O) -> Result<DgdTrace> {
        let d = oracle.dim();
        assert_eq!(self.mu.len(), d);
        let k = self.cfg.k;
        let mut trace = DgdTrace::default();
        let mut tracker = AlignmentTracker::new();
        let mut grad = vec![0.0f32; d];
        let mut gx = vec![0.0f32; d];
        let mut gmu = vec![0.0f32; d];
        // raw standard-normal samples z_k (the score function needs them:
        // for v = mu + eps z, (v - mu)/eps^2 = z/eps)
        let mut zbuf = vec![0.0f32; k * d];
        // normalized directions v̄_k actually used by the DGD estimator
        let mut vbuf = vec![0.0f32; k * d];
        let mut rewards = vec![0.0f32; k];

        for _step in 0..self.cfg.steps {
            let loss = oracle.grad(&mut grad)?;
            let gn = nrm2(&grad);
            trace.loss.push(loss);
            trace.grad_norm.push(gn);

            // sample K directions; keep raw z and normalized v̄ separately
            self.rng.fill_normal(&mut zbuf);
            for i in 0..k {
                let z = &zbuf[i * d..(i + 1) * d];
                let row = &mut vbuf[i * d..(i + 1) * d];
                match self.cfg.variant {
                    DgdVariant::Baseline => row.copy_from_slice(z),
                    DgdVariant::Ldsd => {
                        for j in 0..d {
                            row[j] = self.mu[j] + self.cfg.eps * z[j];
                        }
                    }
                }
                normalize(row);
            }

            // g_x = (1/K) sum_k v̄_k <v̄_k, grad>   (eq. 5)
            gx.iter_mut().for_each(|v| *v = 0.0);
            for i in 0..k {
                let row = &vbuf[i * d..(i + 1) * d];
                let proj = dot(row, &grad);
                axpy(proj / k as f32, row, &mut gx);
                // reward C_k = <v̄_k, grad-bar>^2
                let c = if gn > 0.0 { proj / gn } else { 0.0 };
                rewards[i] = c * c;
            }
            trace.alignment.push(tracker.record(&gx, &grad));
            if self.cfg.variant == DgdVariant::Ldsd {
                trace.mu_alignment.push(cosine(&self.mu, &grad));
                // REINFORCE ascent on the alignment reward with the mean
                // baseline (§3.6):
                //   g_mu = (1/K) sum_k (C_k - b̄) (v_k - mu)/eps^2
                //        = (1/(K eps)) sum_k (C_k - b̄) z_k.
                let baseline: f32 = rewards.iter().sum::<f32>() / k as f32;
                gmu.iter_mut().for_each(|v| *v = 0.0);
                for i in 0..k {
                    let w = rewards[i] - baseline;
                    if w != 0.0 {
                        axpy(w, &zbuf[i * d..(i + 1) * d], &mut gmu);
                    }
                }
                scal(1.0 / (k as f32 * self.cfg.eps), &mut gmu);
                axpy(self.cfg.gamma_mu, &gmu, &mut self.mu);
            }

            // x -= gamma_x g_x
            let gamma = self.cfg.gamma_x;
            oracle.update_params(&mut |x| axpy(-gamma, &gx, x))?;
        }
        Ok(trace)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticRegression;
    use crate::oracle::{LinRegOracle, Oracle};

    fn toy_oracle(seed: u64) -> LinRegOracle {
        let ds = SyntheticRegression::a9a_like(256, seed);
        LinRegOracle::new(ds.x, ds.y, vec![0.0; 123])
    }

    #[test]
    fn baseline_descends() {
        let mut o = toy_oracle(1);
        // modest gamma_x for the synthetic conditioning
        let mut cfg = DgdConfig::paper_baseline(300, 7);
        cfg.gamma_x = 2.0;
        let mut r = DgdRunner::new(cfg, o.dim());
        let t = r.run(&mut o).unwrap();
        assert!(t.loss[299] < t.loss[0] * 0.9, "{} -> {}", t.loss[0], t.loss[299]);
    }

    #[test]
    fn ldsd_alignment_beats_baseline() {
        // Lemma 2 / Fig. 2: LDSD's realized alignment should exceed the
        // O(1/sqrt(d)) baseline cosine by a wide margin late in training.
        let steps = 400;
        let mut ob = toy_oracle(2);
        let mut cfgb = DgdConfig::paper_baseline(steps, 3);
        cfgb.gamma_x = 2.0;
        let mut rb = DgdRunner::new(cfgb, ob.dim());
        let tb = rb.run(&mut ob).unwrap();

        let mut ol = toy_oracle(2);
        // gamma_x/gamma_mu/eps rescaled for the synthetic conditioning,
        // preserving the paper's small-gamma_x-for-LDSD ratio (§A.1 uses
        // 5 vs 200 = 40x smaller than the baseline's step).
        let mut cfgl = DgdConfig::paper_ldsd(steps, 3);
        cfgl.gamma_x = 0.05;
        cfgl.gamma_mu = 0.05;
        cfgl.eps = 0.05;
        let mut rl = DgdRunner::new(cfgl, ol.dim());
        let tl = rl.run(&mut ol).unwrap();

        let tail = |v: &[f32]| -> f32 {
            let s = &v[v.len() - 50..];
            s.iter().sum::<f32>() / s.len() as f32
        };
        let (ab, al) = (tail(&tb.alignment), tail(&tl.alignment));
        assert!(
            al > ab + 0.1,
            "LDSD tail alignment {al} should beat baseline {ab}"
        );
    }

    #[test]
    fn mu_alignment_grows() {
        // |cos(mu, grad)|: C^t depends on the squared cosine, so mu
        // converging to either +-grad-bar is success (Fig. 1 symmetry).
        let mut o = toy_oracle(4);
        let mut cfg = DgdConfig::paper_ldsd(400, 5);
        cfg.gamma_x = 0.05;
        cfg.gamma_mu = 0.05;
        cfg.eps = 0.05;
        let mut r = DgdRunner::new(cfg, o.dim());
        let t = r.run(&mut o).unwrap();
        let early: f32 =
            t.mu_alignment[..20].iter().map(|c| c.abs()).sum::<f32>() / 20.0;
        let late: f32 = t.mu_alignment[t.mu_alignment.len() - 20..]
            .iter()
            .map(|c| c.abs())
            .sum::<f32>()
            / 20.0;
        assert!(
            late > early + 0.2 && late > 0.8,
            "|cos(mu, grad)| should grow: early {early}, late {late}"
        );
    }

    #[test]
    fn trace_lengths_match_steps() {
        let mut o = toy_oracle(6);
        let mut cfg = DgdConfig::paper_baseline(50, 1);
        cfg.gamma_x = 1.0;
        let mut r = DgdRunner::new(cfg, o.dim());
        let t = r.run(&mut o).unwrap();
        assert_eq!(t.alignment.len(), 50);
        assert_eq!(t.grad_norm.len(), 50);
        assert_eq!(t.loss.len(), 50);
        assert!(o.oracle_calls() == 0, "DGD uses the gradient, not the oracle");
    }
}
