//! Base ZO optimizers: consume a gradient surrogate like a first-order
//! method.  Hyperparameters follow the paper's §A.2 (momentum 0.9, Adam
//! betas (0.9, 0.999), JAGUAR beta 0.9).

use crate::tensor::{axpy, sign_into};

/// Serializable persistent optimizer state (the snapshot subsystem's view
/// of an optimizer): integer scalars (step counters) plus f32 moment
/// buffers, in a fixed per-optimizer order.  Captured by
/// [`BaseOptimizer::state`], persisted as raw little-endian blobs by
/// [`crate::snapshot`], and reinstated bit-exactly by
/// [`BaseOptimizer::load_state`].
#[derive(Clone, Debug, Default, PartialEq)]
pub struct OptimizerState {
    /// Integer scalars (e.g. ZO-AdaMM's bias-correction step count).
    pub scalars: Vec<u64>,
    /// Persistent f32 moment buffers (momentum, Adam m/v, ...).
    pub buffers: Vec<Vec<f32>>,
}

impl OptimizerState {
    /// Validate the shape of a restored state against what this optimizer
    /// expects; shared by the `load_state` impls.
    fn expect(
        &self,
        who: &str,
        scalars: usize,
        buffer_lens: &[usize],
    ) -> anyhow::Result<()> {
        if self.scalars.len() != scalars {
            anyhow::bail!(
                "{who}: snapshot has {} scalars, expected {scalars}",
                self.scalars.len()
            );
        }
        if self.buffers.len() != buffer_lens.len() {
            anyhow::bail!(
                "{who}: snapshot has {} buffers, expected {}",
                self.buffers.len(),
                buffer_lens.len()
            );
        }
        for (i, (buf, want)) in self.buffers.iter().zip(buffer_lens.iter()).enumerate() {
            if buf.len() != *want {
                anyhow::bail!(
                    "{who}: snapshot buffer {i} holds {} f32, expected {want}",
                    buf.len()
                );
            }
        }
        Ok(())
    }
}

/// First-order-style update rule fed by a ZO gradient estimate.
pub trait BaseOptimizer {
    /// x -= lr * update(g)
    fn step(&mut self, params: &mut [f32], g: &[f32], lr: f32);

    /// Bytes of persistent optimizer state (memory-table accounting).
    fn state_bytes(&self) -> usize;

    /// Snapshot the persistent state (crash-safe checkpointing).
    fn state(&self) -> OptimizerState;

    /// Restore state captured by [`BaseOptimizer::state`] on an optimizer
    /// built with identical dimensionality and hyperparameters.  The
    /// restored optimizer continues bit-exactly where the snapshot one
    /// stopped.
    fn load_state(&mut self, state: &OptimizerState) -> anyhow::Result<()>;

    /// Short identifier used in labels.
    fn name(&self) -> &str;
}

/// SGD with optional heavy-ball momentum (the paper's ZO-SGD baseline).
pub struct ZoSgd {
    /// Heavy-ball coefficient (0 disables the momentum buffer).
    pub momentum: f32,
    buf: Vec<f32>,
    active: bool,
}

impl ZoSgd {
    /// Build for dimensionality `d`; `momentum = 0` allocates no state.
    pub fn new(d: usize, momentum: f32) -> Self {
        let active = momentum != 0.0;
        Self { momentum, buf: if active { vec![0.0; d] } else { Vec::new() }, active }
    }
}

impl BaseOptimizer for ZoSgd {
    fn step(&mut self, params: &mut [f32], g: &[f32], lr: f32) {
        if self.active {
            // m = beta m + g;  x -= lr m
            for (m, gi) in self.buf.iter_mut().zip(g.iter()) {
                *m = self.momentum * *m + *gi;
            }
            axpy(-lr, &self.buf, params);
        } else {
            axpy(-lr, g, params);
        }
    }

    fn state_bytes(&self) -> usize {
        self.buf.len() * 4
    }

    fn state(&self) -> OptimizerState {
        OptimizerState { scalars: Vec::new(), buffers: vec![self.buf.clone()] }
    }

    fn load_state(&mut self, state: &OptimizerState) -> anyhow::Result<()> {
        state.expect("zo_sgd", 0, &[self.buf.len()])?;
        self.buf.copy_from_slice(&state.buffers[0]);
        Ok(())
    }

    fn name(&self) -> &str {
        "zo_sgd"
    }
}

/// ZO-AdaMM (Chen et al., 2019): Adam moments driven by ZO estimates.
pub struct ZoAdaMM {
    /// First-moment decay.
    pub beta1: f32,
    /// Second-moment decay.
    pub beta2: f32,
    /// Denominator stabilizer.
    pub eps: f32,
    m: Vec<f32>,
    v: Vec<f32>,
    t: u64,
}

impl ZoAdaMM {
    /// Build for dimensionality `d` with the given moment decays.
    pub fn new(d: usize, beta1: f32, beta2: f32) -> Self {
        Self { beta1, beta2, eps: 1e-8, m: vec![0.0; d], v: vec![0.0; d], t: 0 }
    }
}

impl BaseOptimizer for ZoAdaMM {
    fn step(&mut self, params: &mut [f32], g: &[f32], lr: f32) {
        self.t += 1;
        let b1c = 1.0 - self.beta1.powi(self.t as i32);
        let b2c = 1.0 - self.beta2.powi(self.t as i32);
        for i in 0..params.len() {
            self.m[i] = self.beta1 * self.m[i] + (1.0 - self.beta1) * g[i];
            self.v[i] = self.beta2 * self.v[i] + (1.0 - self.beta2) * g[i] * g[i];
            let mh = self.m[i] / b1c;
            let vh = self.v[i] / b2c;
            params[i] -= lr * mh / (vh.sqrt() + self.eps);
        }
    }

    fn state_bytes(&self) -> usize {
        (self.m.len() + self.v.len()) * 4
    }

    fn state(&self) -> OptimizerState {
        OptimizerState {
            scalars: vec![self.t],
            buffers: vec![self.m.clone(), self.v.clone()],
        }
    }

    fn load_state(&mut self, state: &OptimizerState) -> anyhow::Result<()> {
        state.expect("zo_adamm", 1, &[self.m.len(), self.v.len()])?;
        self.t = state.scalars[0];
        self.m.copy_from_slice(&state.buffers[0]);
        self.v.copy_from_slice(&state.buffers[1]);
        Ok(())
    }

    fn name(&self) -> &str {
        "zo_adamm"
    }
}

/// JAGUAR SignSGD (Veprikov et al. 2024 / Petrov et al. 2025): coordinate
/// momentum h = beta h + (1 - beta) g, update x -= lr * sign(h).
pub struct JaguarSignSgd {
    /// Coordinate-momentum decay.
    pub beta: f32,
    h: Vec<f32>,
    sgn: Vec<f32>,
}

impl JaguarSignSgd {
    /// Build for dimensionality `d` with momentum decay `beta`.
    pub fn new(d: usize, beta: f32) -> Self {
        Self { beta, h: vec![0.0; d], sgn: vec![0.0; d] }
    }
}

impl BaseOptimizer for JaguarSignSgd {
    fn step(&mut self, params: &mut [f32], g: &[f32], lr: f32) {
        for (hi, gi) in self.h.iter_mut().zip(g.iter()) {
            *hi = self.beta * *hi + (1.0 - self.beta) * *gi;
        }
        sign_into(&mut self.sgn, &self.h);
        axpy(-lr, &self.sgn, params);
    }

    fn state_bytes(&self) -> usize {
        self.h.len() * 4 // sign scratch is transient
    }

    fn state(&self) -> OptimizerState {
        // sgn is per-step scratch, recomputed from h before every use
        OptimizerState { scalars: Vec::new(), buffers: vec![self.h.clone()] }
    }

    fn load_state(&mut self, state: &OptimizerState) -> anyhow::Result<()> {
        state.expect("jaguar_signsgd", 0, &[self.h.len()])?;
        self.h.copy_from_slice(&state.buffers[0]);
        Ok(())
    }

    fn name(&self) -> &str {
        "jaguar_signsgd"
    }
}

/// Build a base optimizer by name ("zo_sgd" | "zo_adamm" | "jaguar").
pub fn by_name(name: &str, d: usize) -> anyhow::Result<Box<dyn BaseOptimizer + Send>> {
    match name {
        "zo_sgd" => Ok(Box::new(ZoSgd::new(d, 0.9))),
        "zo_sgd_plain" => Ok(Box::new(ZoSgd::new(d, 0.0))),
        "zo_adamm" => Ok(Box::new(ZoAdaMM::new(d, 0.9, 0.999))),
        "jaguar" | "jaguar_signsgd" => Ok(Box::new(JaguarSignSgd::new(d, 0.9))),
        _ => anyhow::bail!("unknown optimizer '{name}'"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sgd_plain_is_gradient_step() {
        let mut opt = ZoSgd::new(3, 0.0);
        let mut x = vec![1.0f32, 2.0, 3.0];
        opt.step(&mut x, &[1.0, 1.0, 1.0], 0.5);
        assert_eq!(x, vec![0.5, 1.5, 2.5]);
        assert_eq!(opt.state_bytes(), 0);
    }

    #[test]
    fn sgd_momentum_accumulates() {
        let mut opt = ZoSgd::new(1, 0.9);
        let mut x = vec![0.0f32];
        opt.step(&mut x, &[1.0], 1.0); // m=1, x=-1
        opt.step(&mut x, &[1.0], 1.0); // m=1.9, x=-2.9
        assert!((x[0] + 2.9).abs() < 1e-6);
        assert_eq!(opt.state_bytes(), 4);
    }

    #[test]
    fn adamm_first_step_is_lr_sized() {
        // with bias correction, |first step| ~ lr regardless of g scale
        for scale in [1e-3f32, 1.0, 1e3] {
            let mut opt = ZoAdaMM::new(1, 0.9, 0.999);
            let mut x = vec![0.0f32];
            opt.step(&mut x, &[scale], 0.01);
            assert!((x[0].abs() - 0.01).abs() < 1e-4, "scale {scale}: {}", x[0]);
        }
    }

    #[test]
    fn jaguar_steps_are_sign_sized() {
        let mut opt = JaguarSignSgd::new(3, 0.0);
        let mut x = vec![0.0f32; 3];
        opt.step(&mut x, &[5.0, -3.0, 0.0], 0.1);
        assert_eq!(x, vec![-0.1, 0.1, 0.0]);
    }

    #[test]
    fn quadratic_converges_under_all_optimizers() {
        // one exact-gradient descent sanity loop per optimizer
        for name in ["zo_sgd", "zo_sgd_plain", "zo_adamm", "jaguar"] {
            let d = 10;
            let mut opt = by_name(name, d).unwrap();
            let mut x = vec![5.0f32; d];
            let lr = match name {
                "zo_adamm" => 0.05,
                "jaguar" => 0.01,
                _ => 0.05,
            };
            let mut g = vec![0.0f32; d];
            for _ in 0..2000 {
                for i in 0..d {
                    g[i] = x[i]; // grad of 0.5||x||^2
                }
                opt.step(&mut x, &g, lr);
            }
            let n: f32 = x.iter().map(|v| v * v).sum::<f32>().sqrt();
            assert!(n < 0.5, "{name} ended at ||x|| = {n}");
        }
    }

    #[test]
    fn by_name_rejects_unknown() {
        assert!(by_name("sgd9000", 4).is_err());
    }

    #[test]
    fn state_roundtrip_continues_bit_exactly() {
        // For every optimizer: run a steps, snapshot, run b more steps;
        // a twin restored from the snapshot must walk the identical
        // continuation bit for bit.
        for name in ["zo_sgd", "zo_sgd_plain", "zo_adamm", "jaguar"] {
            let d = 6;
            let g = |t: u64| -> Vec<f32> {
                (0..d).map(|i| ((i as f32 + 1.0) * 0.3).sin() + t as f32 * 0.01).collect()
            };
            let mut a = by_name(name, d).unwrap();
            let mut xa = vec![1.0f32; d];
            for t in 0..5 {
                a.step(&mut xa, &g(t), 0.05);
            }
            let saved = a.state();
            let mut b = by_name(name, d).unwrap();
            b.load_state(&saved).unwrap();
            let mut xb = xa.clone();
            for t in 5..10 {
                a.step(&mut xa, &g(t), 0.05);
                b.step(&mut xb, &g(t), 0.05);
            }
            for (p, q) in xa.iter().zip(xb.iter()) {
                assert_eq!(p.to_bits(), q.to_bits(), "{name} diverged after restore");
            }
        }
    }

    #[test]
    fn load_state_rejects_wrong_shapes() {
        let mut opt = ZoAdaMM::new(4, 0.9, 0.999);
        let err = opt.load_state(&OptimizerState::default()).unwrap_err();
        assert!(err.to_string().contains("zo_adamm"), "{err}");
        let mut sgd = ZoSgd::new(3, 0.9);
        let bad = OptimizerState { scalars: vec![], buffers: vec![vec![0.0; 7]] };
        assert!(sgd.load_state(&bad).is_err());
    }
}
