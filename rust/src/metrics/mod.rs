//! Metrics: memory accounting (the paper's §1 motivation) and run stats.

mod memory;
mod stats;

pub use memory::{
    param_tracker, probe_tracker, MemoryReport, MethodMemory, PeakTracker, TrackedBuf,
};
pub use stats::{mean, percentile, percentile_sorted, stddev, Summary};
