//! Metrics: memory accounting (the paper's §1 motivation) and run stats.

mod memory;
mod stats;

pub use memory::{MemoryReport, MethodMemory};
pub use stats::{mean, percentile, stddev, Summary};
