//! Optimizer-pipeline memory accounting.
//!
//! The paper's motivation (§1) is that backprop fine-tuning stores
//! activations + optimizer state on top of weights, while ZO methods need
//! only forward activations plus O(d) (or zero) method state.  This module
//! computes the per-method footprint from first principles so the
//! `memory_table` bench can print a ZO-vs-FO comparison for our models —
//! structured exactly like the paper's "12x more than inference" claim.

/// Byte accounting for one fine-tuning method on one model.
#[derive(Clone, Debug)]
pub struct MethodMemory {
    /// Method label ("fo_adam", "zo_sgd (gaussian)", ...).
    pub method: String,
    /// model weights (shared by everything)
    pub weights: usize,
    /// gradient buffer (backprop only)
    pub gradients: usize,
    /// stored activations for the backward pass (backprop only)
    pub activations_backward: usize,
    /// peak transient activations of one forward pass
    pub activations_forward: usize,
    /// optimizer moments (Adam 2d, momentum d, ...)
    pub optimizer_state: usize,
    /// estimator/sampler state (LDSD mu is d floats; dirs buffer K x d_t)
    pub method_state: usize,
}

impl MethodMemory {
    /// Total bytes across all components.
    pub fn total(&self) -> usize {
        self.weights
            + self.gradients
            + self.activations_backward
            + self.activations_forward
            + self.optimizer_state
            + self.method_state
    }

    /// Ratio over pure inference (weights + forward activations).
    pub fn over_inference(&self) -> f64 {
        let inf = (self.weights + self.activations_forward) as f64;
        self.total() as f64 / inf
    }
}

/// Forward activation estimate for our transformer stand-ins:
/// per layer ~ (attention scores B*H*S*S + activations B*S*(4 d_model + d_ff)),
/// f32.  `checkpointed` keeps only one layer live (inference / ZO);
/// backprop keeps all layers.
pub fn activation_bytes(
    batch: usize,
    seq: usize,
    d_model: usize,
    d_ff: usize,
    n_heads: usize,
    n_layers: usize,
    all_layers: bool,
) -> usize {
    let per_layer =
        batch * n_heads * seq * seq + batch * seq * (4 * d_model + d_ff);
    let layers = if all_layers { n_layers } else { 1 };
    4 * per_layer * layers
}

/// Build the ZO-vs-FO comparison for a model with `d` trainable and
/// `d_total` total parameters.
pub struct MemoryReport;

impl MemoryReport {
    /// Compute per-method footprints for one model configuration.
    #[allow(clippy::too_many_arguments)]
    pub fn build(
        d_trainable: usize,
        d_total: usize,
        batch: usize,
        seq: usize,
        d_model: usize,
        d_ff: usize,
        n_heads: usize,
        n_layers: usize,
        k: usize,
    ) -> Vec<MethodMemory> {
        let w = 4 * d_total;
        let fwd = activation_bytes(batch, seq, d_model, d_ff, n_heads, n_layers, false);
        let bwd = activation_bytes(batch, seq, d_model, d_ff, n_heads, n_layers, true);
        let dirs = 4 * d_trainable; // one direction buffer, reused across K probes
        let g = 4 * d_trainable; // dense gradient surrogate buffer
        vec![
            MethodMemory {
                method: "inference".into(),
                weights: w,
                gradients: 0,
                activations_backward: 0,
                activations_forward: fwd,
                optimizer_state: 0,
                method_state: 0,
            },
            MethodMemory {
                method: "fo_sgd_momentum".into(),
                weights: w,
                gradients: 4 * d_trainable,
                activations_backward: bwd,
                activations_forward: fwd,
                optimizer_state: 4 * d_trainable,
                method_state: 0,
            },
            MethodMemory {
                method: "fo_adam".into(),
                weights: w,
                gradients: 4 * d_trainable,
                activations_backward: bwd,
                activations_forward: fwd,
                optimizer_state: 8 * d_trainable,
                method_state: 0,
            },
            MethodMemory {
                method: "zo_sgd (gaussian)".into(),
                weights: w,
                gradients: 0,
                activations_backward: 0,
                activations_forward: fwd,
                optimizer_state: 4 * d_trainable, // momentum
                method_state: dirs + g,
            },
            MethodMemory {
                method: "zo_adamm (gaussian)".into(),
                weights: w,
                gradients: 0,
                activations_backward: 0,
                activations_forward: fwd,
                optimizer_state: 8 * d_trainable,
                method_state: dirs + g,
            },
            MethodMemory {
                method: format!("zo_sgd + LDSD (K={k})"),
                weights: w,
                gradients: 0,
                activations_backward: 0,
                activations_forward: fwd,
                optimizer_state: 4 * d_trainable,
                // mu policy (d) + K direction rows + g
                method_state: 4 * d_trainable + 4 * k * d_trainable + g,
            },
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report() -> Vec<MethodMemory> {
        // roberta_mini-ish numbers
        MemoryReport::build(1_321_986, 1_321_986, 8, 32, 128, 512, 4, 4, 5)
    }

    #[test]
    fn zo_beats_fo_adam() {
        let r = report();
        let adam = r.iter().find(|m| m.method == "fo_adam").unwrap();
        let zo = r.iter().find(|m| m.method.starts_with("zo_sgd (")).unwrap();
        assert!(zo.total() < adam.total());
    }

    #[test]
    fn fo_overhead_over_inference_is_multiples() {
        let r = report();
        let adam = r.iter().find(|m| m.method == "fo_adam").unwrap();
        assert!(
            adam.over_inference() > 3.0,
            "adam/inference = {}",
            adam.over_inference()
        );
    }

    #[test]
    fn ldsd_overhead_is_order_d() {
        let r = report();
        let zo = r.iter().find(|m| m.method.starts_with("zo_sgd (")).unwrap();
        let ldsd = r.iter().find(|m| m.method.contains("LDSD")).unwrap();
        let extra = ldsd.total() - zo.total();
        // mu + (K-1 extra dir rows): (1 + K) * 4d  with K=5 -> 24 d bytes
        assert_eq!(extra, (1 + 5) * 4 * 1_321_986 - 4 * 1_321_986);
    }

    #[test]
    fn lora_mode_shrinks_state() {
        let lora = MemoryReport::build(16_642, 1_321_986, 8, 32, 128, 512, 4, 4, 5);
        let adam = lora.iter().find(|m| m.method == "fo_adam").unwrap();
        // optimizer state is tied to trainables, not total weights
        assert!(adam.optimizer_state < 4 * 1_321_986);
    }
}
