//! Optimizer-pipeline memory accounting.
//!
//! The paper's motivation (§1) is that backprop fine-tuning stores
//! activations + optimizer state on top of weights, while ZO methods need
//! only forward activations plus O(d) (or zero) method state.  This module
//! computes the per-method footprint from first principles so the
//! `memory_table` bench can print a ZO-vs-FO comparison for our models —
//! structured exactly like the paper's "12x more than inference" claim.
//!
//! Alongside the analytical report lives [`PeakTracker`], the *measured*
//! side of the same claim: probe-state buffers (the materialized K x d
//! matrix, or the streamed engine's per-worker shard scratch) register
//! their allocations with the global [`probe_tracker`], and the
//! coordinator resets it per trial so grid summaries report true per-trial
//! peaks (DESIGN.md §10).

use std::sync::atomic::{AtomicUsize, Ordering};

/// High-water tracker for transient probe-state bytes.
///
/// `add`/`sub` maintain the currently-live byte count; `peak` is the
/// maximum the live count has reached since the last [`PeakTracker::reset`].
/// Reset clamps the peak back to the *currently live* bytes (not zero), so
/// long-lived buffers allocated before a trial still count toward that
/// trial's peak while high-water marks of earlier trials do not leak into
/// later ones.
#[derive(Debug, Default)]
pub struct PeakTracker {
    current: AtomicUsize,
    peak: AtomicUsize,
}

impl PeakTracker {
    /// An empty tracker.
    pub const fn new() -> Self {
        Self { current: AtomicUsize::new(0), peak: AtomicUsize::new(0) }
    }

    /// Register `bytes` of newly-allocated probe state.
    pub fn add(&self, bytes: usize) {
        let now = self.current.fetch_add(bytes, Ordering::Relaxed) + bytes;
        self.peak.fetch_max(now, Ordering::Relaxed);
    }

    /// Unregister `bytes` of freed probe state.
    pub fn sub(&self, bytes: usize) {
        self.current.fetch_sub(bytes, Ordering::Relaxed);
    }

    /// Currently-live tracked bytes.
    pub fn current(&self) -> usize {
        self.current.load(Ordering::Relaxed)
    }

    /// High-water mark since the last reset.
    pub fn peak(&self) -> usize {
        self.peak.load(Ordering::Relaxed)
    }

    /// Start a new measurement window: the peak becomes the currently-live
    /// byte count.  The coordinator calls this at the start of every trial
    /// so a trial never inherits the high-water mark of an earlier one.
    pub fn reset(&self) {
        self.peak.store(self.current.load(Ordering::Relaxed), Ordering::Relaxed);
    }
}

/// The process-wide tracker for probe-state buffers (probe matrices and
/// streaming shard scratch).  Per-trial readings are exact for serial
/// trial schedules; when the coordinator runs trials concurrently the
/// shared state cuts both ways (a neighbour's buffers inflate a reading,
/// a neighbour's reset can clamp a transient peak away), so
/// concurrent-grid readings are indicative only.
pub fn probe_tracker() -> &'static PeakTracker {
    static TRACKER: PeakTracker = PeakTracker::new();
    &TRACKER
}

/// The process-wide tracker for resident parameter bytes.  Every
/// [`crate::tensor::ParamStore`] registers its representation bytes here
/// for its lifetime, so the memory-table bench can report *measured*
/// f32-vs-f16-vs-int8 residency alongside the analytical table.  Kept
/// separate from [`probe_tracker`] because parameters are long-lived
/// (their "peak" is just residency) while probe state is transient.
pub fn param_tracker() -> &'static PeakTracker {
    static TRACKER: PeakTracker = PeakTracker::new();
    &TRACKER
}

/// RAII f32 buffer registered with the global [`probe_tracker`] for its
/// lifetime.  Probe matrices and the streamed engine's per-worker shard
/// scratch allocate through this, so measured per-trial peaks cover every
/// probe-state byte — the instrumentation behind the "no K x d buffer
/// when streaming" acceptance test.
pub struct TrackedBuf {
    buf: Vec<f32>,
}

impl TrackedBuf {
    /// Allocate a zero-filled tracked buffer of `len` f32 elements.
    pub fn zeroed(len: usize) -> Self {
        probe_tracker().add(len * 4);
        Self { buf: vec![0.0; len] }
    }
}

impl Drop for TrackedBuf {
    fn drop(&mut self) {
        probe_tracker().sub(self.buf.len() * 4);
    }
}

impl std::ops::Deref for TrackedBuf {
    type Target = [f32];
    fn deref(&self) -> &[f32] {
        &self.buf
    }
}

impl std::ops::DerefMut for TrackedBuf {
    fn deref_mut(&mut self) -> &mut [f32] {
        &mut self.buf
    }
}

/// Byte accounting for one fine-tuning method on one model.
#[derive(Clone, Debug)]
pub struct MethodMemory {
    /// Method label ("fo_adam", "zo_sgd (gaussian)", ...).
    pub method: String,
    /// model weights (shared by everything)
    pub weights: usize,
    /// gradient buffer (backprop only)
    pub gradients: usize,
    /// stored activations for the backward pass (backprop only)
    pub activations_backward: usize,
    /// peak transient activations of one forward pass
    pub activations_forward: usize,
    /// optimizer moments (Adam 2d, momentum d, ...)
    pub optimizer_state: usize,
    /// estimator/sampler state (LDSD mu is d floats; dirs buffer K x d_t)
    pub method_state: usize,
}

impl MethodMemory {
    /// Total bytes across all components.
    pub fn total(&self) -> usize {
        self.weights
            + self.gradients
            + self.activations_backward
            + self.activations_forward
            + self.optimizer_state
            + self.method_state
    }

    /// Ratio over pure inference (weights + forward activations).
    pub fn over_inference(&self) -> f64 {
        let inf = (self.weights + self.activations_forward) as f64;
        self.total() as f64 / inf
    }
}

/// Forward activation estimate for our transformer stand-ins:
/// per layer ~ (attention scores B*H*S*S + activations B*S*(4 d_model + d_ff)),
/// f32.  `checkpointed` keeps only one layer live (inference / ZO);
/// backprop keeps all layers.
pub fn activation_bytes(
    batch: usize,
    seq: usize,
    d_model: usize,
    d_ff: usize,
    n_heads: usize,
    n_layers: usize,
    all_layers: bool,
) -> usize {
    let per_layer =
        batch * n_heads * seq * seq + batch * seq * (4 * d_model + d_ff);
    let layers = if all_layers { n_layers } else { 1 };
    4 * per_layer * layers
}

/// Build the ZO-vs-FO comparison for a model with `d` trainable and
/// `d_total` total parameters.
pub struct MemoryReport;

impl MemoryReport {
    /// Compute per-method footprints for one model configuration.
    #[allow(clippy::too_many_arguments)]
    pub fn build(
        d_trainable: usize,
        d_total: usize,
        batch: usize,
        seq: usize,
        d_model: usize,
        d_ff: usize,
        n_heads: usize,
        n_layers: usize,
        k: usize,
    ) -> Vec<MethodMemory> {
        let w = 4 * d_total;
        let fwd = activation_bytes(batch, seq, d_model, d_ff, n_heads, n_layers, false);
        let bwd = activation_bytes(batch, seq, d_model, d_ff, n_heads, n_layers, true);
        let dirs = 4 * d_trainable; // one direction buffer, reused across K probes
        let g = 4 * d_trainable; // dense gradient surrogate buffer
        vec![
            MethodMemory {
                method: "inference".into(),
                weights: w,
                gradients: 0,
                activations_backward: 0,
                activations_forward: fwd,
                optimizer_state: 0,
                method_state: 0,
            },
            MethodMemory {
                method: "fo_sgd_momentum".into(),
                weights: w,
                gradients: 4 * d_trainable,
                activations_backward: bwd,
                activations_forward: fwd,
                optimizer_state: 4 * d_trainable,
                method_state: 0,
            },
            MethodMemory {
                method: "fo_adam".into(),
                weights: w,
                gradients: 4 * d_trainable,
                activations_backward: bwd,
                activations_forward: fwd,
                optimizer_state: 8 * d_trainable,
                method_state: 0,
            },
            MethodMemory {
                method: "zo_sgd (gaussian)".into(),
                weights: w,
                gradients: 0,
                activations_backward: 0,
                activations_forward: fwd,
                optimizer_state: 4 * d_trainable, // momentum
                method_state: dirs + g,
            },
            MethodMemory {
                method: "zo_adamm (gaussian)".into(),
                weights: w,
                gradients: 0,
                activations_backward: 0,
                activations_forward: fwd,
                optimizer_state: 8 * d_trainable,
                method_state: dirs + g,
            },
            MethodMemory {
                method: format!("zo_sgd + LDSD (K={k})"),
                weights: w,
                gradients: 0,
                activations_backward: 0,
                activations_forward: fwd,
                optimizer_state: 4 * d_trainable,
                // mu policy (d) + K direction rows + g
                method_state: 4 * d_trainable + 4 * k * d_trainable + g,
            },
            MethodMemory {
                method: format!("zo_sgd + LDSD (K={k}, streamed)"),
                weights: w,
                gradients: 0,
                activations_backward: 0,
                activations_forward: fwd,
                optimizer_state: 4 * d_trainable,
                // mu policy (d) + g; the K x d probe matrix is replaced by
                // per-worker shard scratch regenerated from RNG cells
                // (DESIGN.md §10) — (K + 1) shards per worker, one worker
                // counted here (the analytical table is per-stream)
                method_state: 4 * d_trainable
                    + 4 * (k + 1) * crate::exec::DEFAULT_SHARD_LEN
                    + g,
            },
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report() -> Vec<MethodMemory> {
        // roberta_mini-ish numbers
        MemoryReport::build(1_321_986, 1_321_986, 8, 32, 128, 512, 4, 4, 5)
    }

    #[test]
    fn zo_beats_fo_adam() {
        let r = report();
        let adam = r.iter().find(|m| m.method == "fo_adam").unwrap();
        let zo = r.iter().find(|m| m.method.starts_with("zo_sgd (")).unwrap();
        assert!(zo.total() < adam.total());
    }

    #[test]
    fn fo_overhead_over_inference_is_multiples() {
        let r = report();
        let adam = r.iter().find(|m| m.method == "fo_adam").unwrap();
        assert!(
            adam.over_inference() > 3.0,
            "adam/inference = {}",
            adam.over_inference()
        );
    }

    #[test]
    fn ldsd_overhead_is_order_d() {
        let r = report();
        let zo = r.iter().find(|m| m.method.starts_with("zo_sgd (")).unwrap();
        let ldsd = r.iter().find(|m| m.method.contains("LDSD")).unwrap();
        let extra = ldsd.total() - zo.total();
        // mu + (K-1 extra dir rows): (1 + K) * 4d  with K=5 -> 24 d bytes
        assert_eq!(extra, (1 + 5) * 4 * 1_321_986 - 4 * 1_321_986);
    }

    #[test]
    fn lora_mode_shrinks_state() {
        let lora = MemoryReport::build(16_642, 1_321_986, 8, 32, 128, 512, 4, 4, 5);
        let adam = lora.iter().find(|m| m.method == "fo_adam").unwrap();
        // optimizer state is tied to trainables, not total weights
        assert!(adam.optimizer_state < 4 * 1_321_986);
    }

    #[test]
    fn streamed_ldsd_drops_the_kd_term() {
        let r = report();
        let mat = r.iter().find(|m| m.method == "zo_sgd + LDSD (K=5)").unwrap();
        let st = r
            .iter()
            .find(|m| m.method == "zo_sgd + LDSD (K=5, streamed)")
            .unwrap();
        // K x d (26 MiB here) replaced by (K+1) shards (1.5 MiB)
        assert!(st.method_state < mat.method_state);
        assert_eq!(
            mat.method_state - st.method_state,
            4 * 5 * 1_321_986 - 4 * 6 * crate::exec::DEFAULT_SHARD_LEN
        );
    }

    #[test]
    fn peak_tracker_tracks_high_water() {
        let t = PeakTracker::new();
        t.add(100);
        t.add(50);
        t.sub(100);
        assert_eq!(t.current(), 50);
        assert_eq!(t.peak(), 150);
    }

    #[test]
    fn peak_tracker_reset_is_per_trial() {
        // the coordinator bug this guards against: without the per-trial
        // reset, a later (smaller) trial reports the earlier trial's peak
        let t = PeakTracker::new();
        t.add(1000); // trial 1
        t.sub(1000);
        assert_eq!(t.peak(), 1000);
        t.reset(); // trial 2 starts
        assert_eq!(t.peak(), 0);
        t.add(10);
        t.sub(10);
        assert_eq!(t.peak(), 10, "trial 2 must see its own peak, not 1000");
    }

    #[test]
    fn peak_tracker_reset_keeps_live_bytes() {
        let t = PeakTracker::new();
        t.add(300); // long-lived buffer from before the trial
        t.reset();
        assert_eq!(t.peak(), 300, "live buffers still count after reset");
    }
}
