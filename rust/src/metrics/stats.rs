//! Small statistics helpers shared by benches and reports.

/// Arithmetic mean (NaN for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (0 for fewer than two samples).
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64)
        .sqrt()
}

/// Linear-interpolated percentile, p in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    let rank = (p / 100.0) * (s.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        s[lo] + (rank - lo as f64) * (s[hi] - s[lo])
    }
}

/// Five-number-ish summary used by the bench harness tables.
#[derive(Clone, Debug)]
pub struct Summary {
    /// Sample count.
    pub n: usize,
    /// Arithmetic mean.
    pub mean: f64,
    /// Sample standard deviation.
    pub std: f64,
    /// Median.
    pub p50: f64,
    /// 95th percentile.
    pub p95: f64,
    /// Smallest sample.
    pub min: f64,
    /// Largest sample.
    pub max: f64,
}

impl Summary {
    /// Summarize a sample set.
    pub fn of(xs: &[f64]) -> Self {
        Self {
            n: xs.len(),
            mean: mean(xs),
            std: stddev(xs),
            p50: percentile(xs, 50.0),
            p95: percentile(xs, 95.0),
            min: xs.iter().cloned().fold(f64::INFINITY, f64::min),
            max: xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((stddev(&xs) - 1.2909944487).abs() < 1e-6);
    }

    #[test]
    fn percentiles() {
        let xs = [4.0, 1.0, 3.0, 2.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn summary_consistent() {
        let xs = [5.0, 1.0, 3.0];
        let s = Summary::of(&xs);
        assert_eq!(s.n, 3);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
    }

    #[test]
    fn empty_inputs_do_not_panic() {
        assert!(mean(&[]).is_nan());
        assert_eq!(stddev(&[]), 0.0);
        assert!(percentile(&[], 50.0).is_nan());
    }
}
