//! Small statistics helpers shared by benches and reports.

/// Arithmetic mean (NaN for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (0 for fewer than two samples).
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64)
        .sqrt()
}

/// Linear-interpolated percentile, p in [0, 100].  Clones and sorts the
/// sample set; callers taking several percentiles of one set should sort
/// once and use [`percentile_sorted`] (as [`Summary::of`] does).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut s = xs.to_vec();
    sort_samples(&mut s);
    percentile_sorted(&s, p)
}

/// Linear-interpolated percentile over an already ascending-sorted slice
/// — no clone, no re-sort.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        sorted[lo] + (rank - lo as f64) * (sorted[hi] - sorted[lo])
    }
}

fn sort_samples(s: &mut [f64]) {
    s.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
}

/// Five-number-ish summary used by the bench harness tables.
#[derive(Clone, Debug)]
pub struct Summary {
    /// Sample count.
    pub n: usize,
    /// Arithmetic mean.
    pub mean: f64,
    /// Sample standard deviation.
    pub std: f64,
    /// Median.
    pub p50: f64,
    /// 95th percentile.
    pub p95: f64,
    /// Smallest sample.
    pub min: f64,
    /// Largest sample.
    pub max: f64,
}

impl Summary {
    /// Summarize a sample set (one sort, shared by both percentiles).
    ///
    /// An empty set yields an *explicit* empty summary: `n = 0` with every
    /// statistic NaN — never the `min = +inf` / `max = -inf` fold
    /// identities, which `jsonio` would silently render as `null` in
    /// bench/report JSON.  Serialize through [`Summary::to_json`], which
    /// keeps `n` and omits non-finite fields.
    pub fn of(xs: &[f64]) -> Self {
        if xs.is_empty() {
            return Self {
                n: 0,
                mean: f64::NAN,
                std: f64::NAN,
                p50: f64::NAN,
                p95: f64::NAN,
                min: f64::NAN,
                max: f64::NAN,
            };
        }
        let mut sorted = xs.to_vec();
        sort_samples(&mut sorted);
        Self {
            n: xs.len(),
            mean: mean(xs),
            std: stddev(xs),
            p50: percentile_sorted(&sorted, 50.0),
            p95: percentile_sorted(&sorted, 95.0),
            min: xs.iter().cloned().fold(f64::INFINITY, f64::min),
            max: xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
        }
    }

    /// True when this summarizes zero samples.
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// JSON object with `n` always present and non-finite statistics
    /// *omitted* — an empty summary serializes as `{"n": 0}` rather than
    /// a row of `null` stand-ins.
    pub fn to_json(&self) -> crate::jsonio::Json {
        use crate::jsonio::Json;
        let mut m = std::collections::BTreeMap::new();
        m.insert("n".to_string(), Json::Num(self.n as f64));
        for (key, val) in [
            ("mean", self.mean),
            ("std", self.std),
            ("p50", self.p50),
            ("p95", self.p95),
            ("min", self.min),
            ("max", self.max),
        ] {
            if val.is_finite() {
                m.insert(key.to_string(), Json::Num(val));
            }
        }
        Json::Obj(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((stddev(&xs) - 1.2909944487).abs() < 1e-6);
    }

    #[test]
    fn percentiles() {
        let xs = [4.0, 1.0, 3.0, 2.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn summary_consistent() {
        let xs = [5.0, 1.0, 3.0];
        let s = Summary::of(&xs);
        assert_eq!(s.n, 3);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
    }

    #[test]
    fn empty_inputs_do_not_panic() {
        assert!(mean(&[]).is_nan());
        assert_eq!(stddev(&[]), 0.0);
        assert!(percentile(&[], 50.0).is_nan());
        assert!(percentile_sorted(&[], 50.0).is_nan());
    }

    #[test]
    fn percentile_sorted_matches_percentile() {
        let xs = [4.0, 1.0, 3.0, 2.0, 9.5];
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for p in [0.0, 12.5, 50.0, 95.0, 100.0] {
            assert_eq!(percentile(&xs, p).to_bits(), percentile_sorted(&sorted, p).to_bits());
        }
    }

    #[test]
    fn empty_summary_is_explicit_never_infinite() {
        // regression: Summary::of(&[]) used to emit min = +inf / max =
        // -inf, which jsonio turns into `null` in bench/report JSON
        let s = Summary::of(&[]);
        assert!(s.is_empty());
        assert_eq!(s.n, 0);
        for v in [s.mean, s.std, s.p50, s.p95, s.min, s.max] {
            assert!(v.is_nan(), "empty-summary field must be NaN, got {v}");
        }
        let text = crate::jsonio::to_string_pretty(&s.to_json());
        assert!(!text.contains("null"), "empty summary leaked null: {text}");
        assert!(text.contains("\"n\": 0"), "{text}");
    }

    #[test]
    fn summary_json_roundtrips_nonempty() {
        let s = Summary::of(&[1.0, 2.0, 3.0]);
        let text = crate::jsonio::to_string_pretty(&s.to_json());
        assert!(!text.contains("null"), "{text}");
        let back = crate::jsonio::parse(&text).unwrap();
        assert_eq!(back.get("n").unwrap().as_usize(), Some(3));
        assert_eq!(back.get("min").unwrap().as_f64(), Some(1.0));
        assert_eq!(back.get("max").unwrap().as_f64(), Some(3.0));
    }
}
