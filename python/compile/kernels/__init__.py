"""L1 Pallas kernels for the ZO-LDSD compute hot path.

All kernels run with interpret=True so the lowered HLO is plain XLA ops the
CPU PJRT client can execute (real-TPU Mosaic lowering is compile-only on
this testbed).  Correctness oracle: kernels.ref, enforced by
python/tests/test_kernels.py.
"""

from .attention import attention
from .layernorm import layernorm
from .lora import lora_matmul
from .perturb import axpy, perturb_normalize

__all__ = ["attention", "layernorm", "lora_matmul", "axpy", "perturb_normalize"]
