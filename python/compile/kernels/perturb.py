"""L1 Pallas kernel: fused ZO parameter perturbation (axpy).

The zero-order hot loop (Algorithm 2, lines 4-5) evaluates f(x + tau*v) for
K candidate directions.  For a d-parameter model each probe needs an O(d)
axpy before the forward pass; this kernel streams params and direction
through VMEM in fixed-size blocks so the perturbed copy never materializes
in HBM twice.  interpret=True keeps it CPU-runnable (DESIGN.md §7).

The d axis is padded by the caller to a multiple of BLOCK.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# 64k f32 = 256 KiB per operand block: 3 operands resident ~= 0.75 MiB of
# VMEM, safely under the ~16 MiB/core budget with double buffering.
BLOCK = 65536


def _axpy_kernel(x_ref, d_ref, s_ref, o_ref):
    o_ref[...] = x_ref[...] + s_ref[0] * d_ref[...]


def _pad(x: jnp.ndarray, block: int) -> jnp.ndarray:
    n = x.shape[0]
    rem = (-n) % block
    if rem:
        x = jnp.concatenate([x, jnp.zeros((rem,), dtype=x.dtype)])
    return x


def axpy(x: jnp.ndarray, d: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    """x + scale * d for flat f32[d]; scale is a scalar (or shape-(1,)) array."""
    n = x.shape[0]
    block = min(BLOCK, n) if n > 0 else 1
    xp = _pad(x, block)
    dp = _pad(d, block)
    s = jnp.reshape(scale.astype(jnp.float32), (1,))
    grid = (xp.shape[0] // block,)
    out = pl.pallas_call(
        _axpy_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(xp.shape, jnp.float32),
        interpret=True,
    )(xp, dp, s)
    return out[:n]


def perturb_normalize(
    x: jnp.ndarray, d: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-12
) -> jnp.ndarray:
    """x + scale * d/||d||: Algorithm 1 (normalized-direction) variant.

    The norm is a global reduction, computed once outside the blocked kernel;
    the O(d) axpy still streams through the Pallas kernel.
    """
    nrm = jnp.sqrt(jnp.sum(d * d) + eps)
    return axpy(x, d, scale / nrm)
