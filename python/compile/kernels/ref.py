"""Pure-jnp reference oracle for every Pallas kernel in this package.

Each function here is the semantic ground truth the corresponding Pallas
kernel (attention.py / perturb.py / lora.py / layernorm.py) is tested
against in python/tests/test_kernels.py.  Keep these boring and obviously
correct: no tiling, no trickery, just jnp.
"""

from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e9


def softmax_ref(x: jnp.ndarray, axis: int = -1) -> jnp.ndarray:
    """Numerically-stable softmax."""
    m = jnp.max(x, axis=axis, keepdims=True)
    e = jnp.exp(x - m)
    return e / jnp.sum(e, axis=axis, keepdims=True)


def attention_ref(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    mask: jnp.ndarray,
    causal: bool = False,
) -> jnp.ndarray:
    """Single-head scaled dot-product attention.

    q, k, v: [S, Dh]; mask: [S] with 1.0 for valid tokens, 0.0 for padding.
    Returns [S, Dh].
    """
    s, dh = q.shape
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))
    scores = (q @ k.T) * scale  # [S, S]
    # key-side padding mask
    scores = scores + (1.0 - mask[None, :]) * NEG_INF
    if causal:
        causal_mask = jnp.tril(jnp.ones((s, s), dtype=bool))
        scores = jnp.where(causal_mask, scores, NEG_INF)
    probs = softmax_ref(scores, axis=-1)
    return probs @ v


def axpy_ref(x: jnp.ndarray, d: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    """x + scale * d — the ZO perturbation hot path (Algorithm 2, lines 4-5)."""
    return x + scale * d


def perturb_normalize_ref(
    x: jnp.ndarray, d: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-12
) -> jnp.ndarray:
    """x + scale * d/||d|| — Algorithm 1 style (normalized direction)."""
    nrm = jnp.sqrt(jnp.sum(d * d) + eps)
    return x + scale * (d / nrm)


def lora_matmul_ref(
    x: jnp.ndarray,
    w: jnp.ndarray,
    a: jnp.ndarray,
    b: jnp.ndarray,
    scale: float,
) -> jnp.ndarray:
    """y = x @ W + scale * (x @ A) @ B.

    x: [S, D], w: [D, Dout], a: [D, r], b: [r, Dout].
    """
    return x @ w + scale * ((x @ a) @ b)


def layernorm_ref(
    x: jnp.ndarray, g: jnp.ndarray, b: jnp.ndarray, eps: float = 1e-5
) -> jnp.ndarray:
    """LayerNorm over the last axis.  x: [..., D], g/b: [D]."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def weighted_dir_reduce_ref(
    dirs: jnp.ndarray, weights: jnp.ndarray
) -> jnp.ndarray:
    """(1/K) * sum_i weights[i] * dirs[i]  — the REINFORCE mu-gradient reduce.

    dirs: [K, d], weights: [K].  Returns [d].
    """
    k = dirs.shape[0]
    return jnp.sum(weights[:, None] * dirs, axis=0) / jnp.float32(k)
