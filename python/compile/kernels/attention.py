"""L1 Pallas kernel: fused scaled-dot-product attention.

One grid step handles one (batch, head) pair: the whole QK^T -> mask ->
softmax -> V chain stays in VMEM, which is the TPU analogue of the paper's
GPU "keep the probe forward pass on-chip" hot path (DESIGN.md
§Hardware-Adaptation).  interpret=True lowers the kernel to plain HLO so the
AOT artifact runs on the CPU PJRT client; on a real TPU the same BlockSpec
tiles map to MXU-aligned 128x128 blocks.

Shapes: q, k, v: [BH, S, Dh]; mask: [BH, S] (1.0 valid / 0.0 pad).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e9


def _attn_kernel(q_ref, k_ref, v_ref, m_ref, o_ref, *, causal: bool):
    # Block shapes carry a leading singleton (the grid axis); drop it.
    q = q_ref[0]  # [S, Dh]
    k = k_ref[0]
    v = v_ref[0]
    mask = m_ref[0]  # [S]
    s, dh = q.shape
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))
    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    scores = scores + (1.0 - mask[None, :]) * NEG_INF
    if causal:
        row = jax.lax.broadcasted_iota(jnp.int32, (s, s), 0)
        col = jax.lax.broadcasted_iota(jnp.int32, (s, s), 1)
        scores = jnp.where(col <= row, scores, NEG_INF)
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    probs = e / jnp.sum(e, axis=-1, keepdims=True)
    o_ref[0] = jnp.dot(probs, v, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("causal",))
def attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    mask: jnp.ndarray,
    causal: bool = False,
) -> jnp.ndarray:
    """Fused attention over [BH, S, Dh] with key-padding mask [BH, S]."""
    bh, s, dh = q.shape
    qkv_spec = pl.BlockSpec((1, s, dh), lambda i: (i, 0, 0))
    m_spec = pl.BlockSpec((1, s), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_attn_kernel, causal=causal),
        grid=(bh,),
        in_specs=[qkv_spec, qkv_spec, qkv_spec, m_spec],
        out_specs=qkv_spec,
        out_shape=jax.ShapeDtypeStruct((bh, s, dh), jnp.float32),
        interpret=True,
    )(q, k, v, mask)
