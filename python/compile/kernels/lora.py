"""L1 Pallas kernel: fused LoRA matmul  y = x @ W + scale * (x @ A) @ B.

Tiled over the output dimension: each grid step loads one Dout-block of W
and B into VMEM and recomputes the tiny x@A (r columns) locally — on TPU
recomputing the rank-r projection in VMEM is cheaper than an extra HBM
round-trip for the [S, r] intermediate (DESIGN.md §7).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _lora_kernel(x_ref, w_ref, a_ref, b_ref, o_ref, *, scale: float):
    x = x_ref[...]  # [S, D]
    w = w_ref[...]  # [D, BLK]
    a = a_ref[...]  # [D, r]
    b = b_ref[...]  # [r, BLK]
    base = jnp.dot(x, w, preferred_element_type=jnp.float32)
    delta = jnp.dot(
        jnp.dot(x, a, preferred_element_type=jnp.float32),
        b,
        preferred_element_type=jnp.float32,
    )
    o_ref[...] = base + scale * delta


@functools.partial(jax.jit, static_argnames=("scale",))
def lora_matmul(
    x: jnp.ndarray,
    w: jnp.ndarray,
    a: jnp.ndarray,
    b: jnp.ndarray,
    scale: float,
) -> jnp.ndarray:
    """x: [S, D], w: [D, Dout], a: [D, r], b: [r, Dout] -> [S, Dout]."""
    s, dmodel = x.shape
    dout = w.shape[1]
    r = a.shape[1]
    blk = min(128, dout)
    # pad Dout to a block multiple (TPU lanes want 128-aligned tiles)
    rem = (-dout) % blk
    if rem:
        w = jnp.concatenate([w, jnp.zeros((dmodel, rem), jnp.float32)], axis=1)
        b = jnp.concatenate([b, jnp.zeros((r, rem), jnp.float32)], axis=1)
    dpad = w.shape[1]
    out = pl.pallas_call(
        functools.partial(_lora_kernel, scale=scale),
        grid=(dpad // blk,),
        in_specs=[
            pl.BlockSpec((s, dmodel), lambda i: (0, 0)),
            pl.BlockSpec((dmodel, blk), lambda i: (0, i)),
            pl.BlockSpec((dmodel, r), lambda i: (0, 0)),
            pl.BlockSpec((r, blk), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((s, blk), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((s, dpad), jnp.float32),
        interpret=True,
    )(x, w, a, b)
    return out[:, :dout]
