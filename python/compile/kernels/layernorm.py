"""L1 Pallas kernel: row-wise LayerNorm over [N, D].

Grid over row blocks; mean/variance/normalize fused in VMEM.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

EPS = 1e-5


def _ln_kernel(x_ref, g_ref, b_ref, o_ref):
    x = x_ref[...]  # [BLK, D]
    g = g_ref[...]  # [D]
    b = b_ref[...]
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    o_ref[...] = (x - mu) * jax.lax.rsqrt(var + EPS) * g[None, :] + b[None, :]


def layernorm(x: jnp.ndarray, g: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """x: [N, D]; g, b: [D]."""
    n, d = x.shape
    blk = min(128, n)
    rem = (-n) % blk
    if rem:
        x = jnp.concatenate([x, jnp.zeros((rem, d), jnp.float32)], axis=0)
    npad = x.shape[0]
    out = pl.pallas_call(
        _ln_kernel,
        grid=(npad // blk,),
        in_specs=[
            pl.BlockSpec((blk, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((blk, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((npad, d), jnp.float32),
        interpret=True,
    )(x, g, b)
    return out[:n]
