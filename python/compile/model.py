"""L2: JAX transformer classifier forward passes, built on the L1 kernels.

Everything here is *build-time only*: aot.py lowers the functions below to
HLO text, and the rust coordinator executes the compiled artifacts via PJRT.

Exported graph surface (the artifact ABI, DESIGN.md §2):
  logits(flat, ids, mask)                     -> (logits[B, C],)
  loss(flat, ids, mask, labels)               -> (loss,)
  loss_dir(flat, dir, tau, ids, mask, labels) -> (loss,)         # f(x + tau*dir)
  loss_k(flat, dirs[K,d], tau, ids, mask, labels) -> (losses[K],)
plus the _lora variants taking (base_flat, lora_flat, ...) where only the
LoRA vector is perturbed.
"""

from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from . import params as P
from .configs import ModelConfig
from .kernels import attention, axpy, layernorm, lora_matmul


def _split_heads(x: jnp.ndarray, b: int, s: int, h: int, dh: int) -> jnp.ndarray:
    # [B, S, D] -> [B*H, S, Dh]
    return x.reshape(b, s, h, dh).transpose(0, 2, 1, 3).reshape(b * h, s, dh)


def _merge_heads(x: jnp.ndarray, b: int, s: int, h: int, dh: int) -> jnp.ndarray:
    return x.reshape(b, h, s, dh).transpose(0, 2, 1, 3).reshape(b, s, h * dh)


def forward(
    cfg: ModelConfig,
    p: Dict[str, jnp.ndarray],
    ids: jnp.ndarray,
    mask: jnp.ndarray,
    lora: Optional[Dict[str, jnp.ndarray]] = None,
) -> jnp.ndarray:
    """Transformer classifier forward.  ids: [B, S] i32, mask: [B, S] f32.

    Returns logits [B, n_classes].  When `lora` is given, rank-r deltas are
    applied to W_q / W_v through the fused L1 LoRA kernel and the classifier
    head comes from the LoRA vector (the base head is ignored).
    """
    b, s = ids.shape
    h, dh, d = cfg.n_heads, cfg.head_dim, cfg.d_model

    x = p["tok_emb"][ids] + p["pos_emb"][None, :s, :]
    head_mask = jnp.repeat(mask, h, axis=0)  # [B*H, S]

    for i in range(cfg.n_layers):
        pre = f"layer{i}."
        xn = layernorm(x.reshape(b * s, d), p[pre + "ln1.g"], p[pre + "ln1.b"])
        if lora is not None:
            q = lora_matmul(
                xn, p[pre + "wq"], lora[pre + "lora_q.a"],
                lora[pre + "lora_q.b"], cfg.lora_scale,
            ) + p[pre + "bq"]
            v = lora_matmul(
                xn, p[pre + "wv"], lora[pre + "lora_v.a"],
                lora[pre + "lora_v.b"], cfg.lora_scale,
            ) + p[pre + "bv"]
        else:
            q = xn @ p[pre + "wq"] + p[pre + "bq"]
            v = xn @ p[pre + "wv"] + p[pre + "bv"]
        k = xn @ p[pre + "wk"] + p[pre + "bk"]

        qh = _split_heads(q.reshape(b, s, d), b, s, h, dh)
        kh = _split_heads(k.reshape(b, s, d), b, s, h, dh)
        vh = _split_heads(v.reshape(b, s, d), b, s, h, dh)
        attn = attention(qh, kh, vh, head_mask, causal=cfg.causal)
        attn = _merge_heads(attn, b, s, h, dh).reshape(b * s, d)
        x = x + (attn @ p[pre + "wo"] + p[pre + "bo"]).reshape(b, s, d)

        xn2 = layernorm(x.reshape(b * s, d), p[pre + "ln2.g"], p[pre + "ln2.b"])
        ff = jax.nn.gelu(xn2 @ p[pre + "wf1"] + p[pre + "bf1"])
        x = x + (ff @ p[pre + "wf2"] + p[pre + "bf2"]).reshape(b, s, d)

    xf = layernorm(x.reshape(b * s, d), p["final_ln.g"], p["final_ln.b"])
    xf = xf.reshape(b, s, d)
    if cfg.pool == "cls":
        pooled = xf[:, 0, :]
    else:  # "last": final valid position per example
        last = jnp.maximum(jnp.sum(mask, axis=1).astype(jnp.int32) - 1, 0)
        pooled = xf[jnp.arange(b), last, :]
    hw = lora["head.w"] if lora is not None else p["head.w"]
    hb = lora["head.b"] if lora is not None else p["head.b"]
    return pooled @ hw + hb


def forward_pure(
    cfg: ModelConfig,
    p: Dict[str, jnp.ndarray],
    ids: jnp.ndarray,
    mask: jnp.ndarray,
    lora: Optional[Dict[str, jnp.ndarray]] = None,
) -> jnp.ndarray:
    """Pure-jnp twin of forward(): identical math with no Pallas kernels.

    Used (a) as the L2-level correctness oracle in python/tests and (b) for
    the build-time first-order pretraining pass, which needs autodiff
    (Pallas interpret kernels are not generally differentiable).
    """
    b, s = ids.shape
    h, dh, d = cfg.n_heads, cfg.head_dim, cfg.d_model
    neg = -1e9

    def ln(x, g, bb):
        mu = jnp.mean(x, axis=-1, keepdims=True)
        var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
        return (x - mu) * jax.lax.rsqrt(var + 1e-5) * g + bb

    x = p["tok_emb"][ids] + p["pos_emb"][None, :s, :]
    for i in range(cfg.n_layers):
        pre = f"layer{i}."
        xn = ln(x, p[pre + "ln1.g"], p[pre + "ln1.b"])
        q = xn @ p[pre + "wq"] + p[pre + "bq"]
        v = xn @ p[pre + "wv"] + p[pre + "bv"]
        if lora is not None:
            q = q + cfg.lora_scale * (
                (xn @ lora[pre + "lora_q.a"]) @ lora[pre + "lora_q.b"]
            )
            v = v + cfg.lora_scale * (
                (xn @ lora[pre + "lora_v.a"]) @ lora[pre + "lora_v.b"]
            )
        k = xn @ p[pre + "wk"] + p[pre + "bk"]
        qh = q.reshape(b, s, h, dh).transpose(0, 2, 1, 3)
        kh = k.reshape(b, s, h, dh).transpose(0, 2, 1, 3)
        vh = v.reshape(b, s, h, dh).transpose(0, 2, 1, 3)
        scores = jnp.einsum("bhqd,bhkd->bhqk", qh, kh) / jnp.sqrt(
            jnp.float32(dh)
        )
        scores = scores + (1.0 - mask[:, None, None, :]) * neg
        if cfg.causal:
            tri = jnp.tril(jnp.ones((s, s), dtype=bool))
            scores = jnp.where(tri[None, None], scores, neg)
        probs = jax.nn.softmax(scores, axis=-1)
        attn = jnp.einsum("bhqk,bhkd->bhqd", probs, vh)
        attn = attn.transpose(0, 2, 1, 3).reshape(b, s, d)
        x = x + attn @ p[pre + "wo"] + p[pre + "bo"]
        xn2 = ln(x, p[pre + "ln2.g"], p[pre + "ln2.b"])
        ff = jax.nn.gelu(xn2 @ p[pre + "wf1"] + p[pre + "bf1"])
        x = x + ff @ p[pre + "wf2"] + p[pre + "bf2"]

    xf = ln(x, p["final_ln.g"], p["final_ln.b"])
    if cfg.pool == "cls":
        pooled = xf[:, 0, :]
    else:
        last = jnp.maximum(jnp.sum(mask, axis=1).astype(jnp.int32) - 1, 0)
        pooled = xf[jnp.arange(b), last, :]
    hw = lora["head.w"] if lora is not None else p["head.w"]
    hb = lora["head.b"] if lora is not None else p["head.b"]
    return pooled @ hw + hb


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(logp[jnp.arange(logits.shape[0]), labels])


# ---------------------------------------------------------------------------
# Artifact graphs (FT mode: all params trainable & perturbed)
# ---------------------------------------------------------------------------

def make_ft_fns(cfg: ModelConfig):
    layout = P.ft_layout(cfg)

    def logits_fn(flat, ids, mask):
        return (forward(cfg, P.unflatten(flat, layout), ids, mask),)

    def loss_fn(flat, ids, mask, labels):
        logits = forward(cfg, P.unflatten(flat, layout), ids, mask)
        return (cross_entropy(logits, labels),)

    def loss_dir_fn(flat, direction, tau, ids, mask, labels):
        perturbed = axpy(flat, direction, tau)
        return loss_fn(perturbed, ids, mask, labels)

    def loss_k_fn(flat, dirs, tau, ids, mask, labels):
        def one(direction):
            return loss_dir_fn(flat, direction, tau, ids, mask, labels)[0]

        return (jax.lax.map(one, dirs),)

    return {
        "logits": logits_fn,
        "loss": loss_fn,
        "loss_dir": loss_dir_fn,
        "loss_k": loss_k_fn,
    }


# ---------------------------------------------------------------------------
# Artifact graphs (LoRA mode: only adapters + head trainable & perturbed)
# ---------------------------------------------------------------------------

def make_lora_fns(cfg: ModelConfig):
    base_layout = P.ft_layout(cfg)
    lora_layout = P.lora_layout(cfg)

    def logits_fn(base, lora, ids, mask):
        return (
            forward(
                cfg,
                P.unflatten(base, base_layout),
                ids,
                mask,
                lora=P.unflatten(lora, lora_layout),
            ),
        )

    def loss_fn(base, lora, ids, mask, labels):
        logits = logits_fn(base, lora, ids, mask)[0]
        return (cross_entropy(logits, labels),)

    def loss_dir_fn(base, lora, direction, tau, ids, mask, labels):
        perturbed = axpy(lora, direction, tau)
        return loss_fn(base, perturbed, ids, mask, labels)

    def loss_k_fn(base, lora, dirs, tau, ids, mask, labels):
        def one(direction):
            return loss_dir_fn(base, lora, direction, tau, ids, mask, labels)[0]

        return (jax.lax.map(one, dirs),)

    return {
        "logits": logits_fn,
        "loss": loss_fn,
        "loss_dir": loss_dir_fn,
        "loss_k": loss_k_fn,
    }


# ---------------------------------------------------------------------------
# Toy experiment graph (Fig. 2): linear regression gradient + loss
# ---------------------------------------------------------------------------

def linreg_grad_fn(w, x, y):
    """0.5/N * ||Xw - y||^2 and its gradient — the toy DGD oracle."""
    n = x.shape[0]
    resid = x @ w - y
    loss = 0.5 * jnp.sum(resid * resid) / n
    grad = (x.T @ resid) / n
    return (grad, loss)
