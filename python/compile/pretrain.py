"""Build-time first-order pretraining of the mini model stand-ins.

The paper fine-tunes *pretrained* RoBERTa-Large / OPT-1.3B; we cannot ship
those offline, so the substitution (DESIGN.md §5) is: Adam-pretrain each
mini model on the synthetic corpus here, at artifact-build time (python is
allowed on the compile path only), to a deliberately *partial* fit.  The
rust coordinator then zero-order fine-tunes from that checkpoint on fresh
examples — mirroring the pretrained->fine-tune structure of the paper's
experiments while leaving headroom that Table 1 orderings can resolve.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import corpus, model as M, params as P
from .configs import BuildPlan, CorpusSpec, ModelConfig

# Pretrain consumes train indices from this base upward so the rust
# fine-tuning stream (indices from 0) never overlaps it.
PRETRAIN_INDEX_BASE = 1 << 24


def adam_pretrain(
    cfg: ModelConfig, spec: CorpusSpec, plan: BuildPlan, seed: int = 0
) -> Tuple[np.ndarray, dict]:
    """Returns (flat pretrained params, stats)."""
    layout = P.ft_layout(cfg)
    flat = P.init_ft(cfg, jax.random.PRNGKey(seed))

    def loss_fn(theta, ids, mask, labels):
        logits = M.forward_pure(cfg, P.unflatten(theta, layout), ids, mask)
        return M.cross_entropy(logits, labels)

    grad_fn = jax.jit(jax.value_and_grad(loss_fn))

    b1, b2, eps = 0.9, 0.999, 1e-8
    m = jnp.zeros_like(flat)
    v = jnp.zeros_like(flat)

    @jax.jit
    def step(theta, m, v, t, ids, mask, labels):
        loss, g = jax.value_and_grad(loss_fn)(theta, ids, mask, labels)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / (1 - b1**t)
        vh = v / (1 - b2**t)
        theta = theta - plan.pretrain_lr * mh / (jnp.sqrt(vh) + eps)
        return theta, m, v, loss

    losses = []
    for it in range(plan.pretrain_steps):
        ids, mask, labels = corpus.generate_batch(
            spec, PRETRAIN_INDEX_BASE + it * plan.pretrain_batch,
            plan.pretrain_batch,
        )
        flat, m, v, loss = step(
            flat, m, v, jnp.float32(it + 1),
            jnp.asarray(ids), jnp.asarray(mask), jnp.asarray(labels),
        )
        losses.append(float(loss))

    # held-out accuracy of the pretrained checkpoint
    acc = eval_accuracy(cfg, spec, np.asarray(flat), n_batches=4, batch=64)
    stats = {
        "pretrain_loss_first": losses[0],
        "pretrain_loss_last": losses[-1],
        "pretrain_steps": plan.pretrain_steps,
        "pretrain_accuracy": acc,
    }
    return np.asarray(flat, dtype=np.float32), stats


def reinit_head(cfg: ModelConfig, flat: np.ndarray) -> np.ndarray:
    """Zero the classifier head (standard fine-tuning setup: the downstream
    task gets a new head).  Zero — not random — init: a random hyperplane
    over well-separated features lands anywhere in [0, 1] accuracy, while
    zero logits give exactly chance level, so every fine-tuning run starts
    from the same calibrated point with pretrained features intact."""
    layout = P.ft_layout(cfg)
    out = np.array(flat, dtype=np.float32, copy=True)
    off = 0
    for name, shape in layout:
        n = int(np.prod(shape))
        if name in ("head.w", "head.b"):
            out[off : off + n] = 0.0
        off += n
    return out


def eval_accuracy(
    cfg: ModelConfig, spec: CorpusSpec, flat: np.ndarray,
    n_batches: int = 4, batch: int = 64,
) -> float:
    layout = P.ft_layout(cfg)

    @jax.jit
    def logits_fn(theta, ids, mask):
        return M.forward_pure(cfg, P.unflatten(theta, layout), ids, mask)

    theta = jnp.asarray(flat)
    correct = total = 0
    for i in range(n_batches):
        ids, mask, labels = corpus.test_batch(spec, i, batch)
        lg = logits_fn(theta, jnp.asarray(ids), jnp.asarray(mask))
        pred = np.argmax(np.asarray(lg), axis=-1)
        correct += int((pred == labels).sum())
        total += batch
    return correct / total
