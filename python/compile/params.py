"""Flat parameter layout: the L3<->L2 parameter contract.

The rust coordinator owns model parameters as one flat f32[d] vector (plus a
flat f32[d_lora] vector in LoRA mode); jax unflattens with *static* offsets
so the layout below is an ABI.  Any change here must bump MANIFEST_VERSION
in aot.py — the rust manifest loader checks it.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .configs import ModelConfig

Layout = List[Tuple[str, Tuple[int, ...]]]


def ft_layout(cfg: ModelConfig) -> Layout:
    """Full fine-tuning layout: every model parameter, deterministic order."""
    d, f = cfg.d_model, cfg.d_ff
    out: Layout = [
        ("tok_emb", (cfg.vocab, d)),
        ("pos_emb", (cfg.max_seq, d)),
    ]
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        out += [
            (p + "ln1.g", (d,)), (p + "ln1.b", (d,)),
            (p + "wq", (d, d)), (p + "bq", (d,)),
            (p + "wk", (d, d)), (p + "bk", (d,)),
            (p + "wv", (d, d)), (p + "bv", (d,)),
            (p + "wo", (d, d)), (p + "bo", (d,)),
            (p + "ln2.g", (d,)), (p + "ln2.b", (d,)),
            (p + "wf1", (d, f)), (p + "bf1", (f,)),
            (p + "wf2", (f, d)), (p + "bf2", (d,)),
        ]
    out += [
        ("final_ln.g", (d,)), ("final_ln.b", (d,)),
        ("head.w", (d, cfg.n_classes)), ("head.b", (cfg.n_classes,)),
    ]
    return out


def lora_layout(cfg: ModelConfig) -> Layout:
    """LoRA trainables: rank-r adapters on W_q and W_v of every layer, plus
    the classifier head (standard fine-tuning practice)."""
    d, r = cfg.d_model, cfg.lora_rank
    out: Layout = []
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        out += [
            (p + "lora_q.a", (d, r)), (p + "lora_q.b", (r, d)),
            (p + "lora_v.a", (d, r)), (p + "lora_v.b", (r, d)),
        ]
    out += [("head.w", (d, cfg.n_classes)), ("head.b", (cfg.n_classes,))]
    return out


def layout_size(layout: Layout) -> int:
    return sum(int(np.prod(s)) for _, s in layout)


def unflatten(flat: jnp.ndarray, layout: Layout) -> Dict[str, jnp.ndarray]:
    """Static-offset unflatten (jit-friendly)."""
    out: Dict[str, jnp.ndarray] = {}
    off = 0
    for name, shape in layout:
        n = int(np.prod(shape))
        out[name] = flat[off : off + n].reshape(shape)
        off += n
    assert off == flat.shape[0], f"layout size {off} != flat size {flat.shape[0]}"
    return out


def flatten(params: Dict[str, jnp.ndarray], layout: Layout) -> jnp.ndarray:
    return jnp.concatenate([params[name].reshape(-1) for name, _ in layout])


def init_ft(cfg: ModelConfig, key: jax.Array) -> jnp.ndarray:
    """Flat init for the full model (pre-pretraining)."""
    layout = ft_layout(cfg)
    parts = []
    for name, shape in layout:
        key, sub = jax.random.split(key)
        if name.endswith((".g",)):
            parts.append(jnp.ones(shape, jnp.float32).reshape(-1))
        elif name.endswith((".b", "bq", "bk", "bv", "bo", "bf1", "bf2")):
            parts.append(jnp.zeros(shape, jnp.float32).reshape(-1))
        else:
            parts.append(
                (jax.random.normal(sub, shape, jnp.float32) * 0.02).reshape(-1)
            )
    return jnp.concatenate(parts)


def init_lora(cfg: ModelConfig, key: jax.Array, head_w: jnp.ndarray | None = None,
              head_b: jnp.ndarray | None = None) -> jnp.ndarray:
    """Flat init for LoRA trainables: A ~ N(0, 0.01), B = 0 (delta starts at 0);
    head copied from the pretrained model when provided."""
    layout = lora_layout(cfg)
    parts = []
    for name, shape in layout:
        key, sub = jax.random.split(key)
        if name.endswith("lora_q.a") or name.endswith("lora_v.a"):
            parts.append(
                (jax.random.normal(sub, shape, jnp.float32) * 0.01).reshape(-1)
            )
        elif name.endswith(".b") and "lora" in name:
            parts.append(jnp.zeros(shape, jnp.float32).reshape(-1))
        elif name == "head.w":
            w = head_w if head_w is not None else (
                jax.random.normal(sub, shape, jnp.float32) * 0.02
            )
            parts.append(jnp.asarray(w, jnp.float32).reshape(-1))
        elif name == "head.b":
            b = head_b if head_b is not None else jnp.zeros(shape, jnp.float32)
            parts.append(jnp.asarray(b, jnp.float32).reshape(-1))
        else:  # pragma: no cover - defensive
            raise ValueError(f"unhandled lora param {name}")
    return jnp.concatenate(parts)
