"""Model / corpus / artifact build configuration shared across the compile path.

The rust coordinator consumes the same values through artifacts/manifest.json
(emitted by aot.py); this module is the single python-side source of truth.
"""

from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class ModelConfig:
    """Transformer classifier stand-in (DESIGN.md §6)."""

    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    max_seq: int
    n_classes: int
    causal: bool  # True: OPT-style decoder; False: RoBERTa-style encoder
    pool: str  # "cls" | "last"
    lora_rank: int = 8
    lora_scale: float = 2.0  # alpha / r

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


@dataclass(frozen=True)
class CorpusSpec:
    """Synthetic SST-2-like sentiment corpus (DESIGN.md §5).

    Token id space: 0 = PAD, 1 = CLS/BOS, [2, 2+2*lexicon) = class lexicons
    (positive then negative), the rest neutral.  Examples are generated
    statelessly from (seed, index) with SplitMix64 so the rust data pipeline
    reproduces the identical byte-for-byte stream (golden-tested).
    """

    vocab: int
    seq: int
    n_classes: int = 2
    lexicon: int = 64  # signal tokens per class
    min_len: int = 16
    signal_min: int = 2
    signal_max: int = 6
    contra: float = 0.08  # prob a signal token comes from the wrong lexicon
    noise: float = 0.04  # label flip probability
    seed: int = 0x5EED


@dataclass(frozen=True)
class BuildPlan:
    """Static shapes baked into the AOT artifacts."""

    batch: int = 8  # training batch
    eval_batch: int = 64
    k: int = 5  # candidate directions per step (paper default)
    # Deliberately partial pretraining (DESIGN.md §5): stops around
    # 0.75-0.85 held-out accuracy so zero-order fine-tuning has headroom
    # for the Table 1 orderings to resolve.
    pretrain_steps: int = 120
    pretrain_lr: float = 3e-4
    pretrain_batch: int = 32
    modes: tuple = ("ft", "lora")


ROBERTA_MINI = ModelConfig(
    name="roberta_mini", vocab=4096, d_model=128, n_layers=4, n_heads=4,
    d_ff=512, max_seq=32, n_classes=2, causal=False, pool="cls",
)

OPT_MINI = ModelConfig(
    name="opt_mini", vocab=4096, d_model=160, n_layers=4, n_heads=4,
    d_ff=640, max_seq=32, n_classes=2, causal=True, pool="last",
)

E2E_100M = ModelConfig(
    name="e2e_100m", vocab=32768, d_model=768, n_layers=12, n_heads=12,
    d_ff=3072, max_seq=64, n_classes=2, causal=True, pool="last",
)

MODELS = {m.name: m for m in (ROBERTA_MINI, OPT_MINI, E2E_100M)}

DEFAULT_CORPUS = CorpusSpec(vocab=4096, seq=32)
E2E_CORPUS = CorpusSpec(vocab=32768, seq=64, seed=0xE2E5EED)

DEFAULT_PLAN = BuildPlan()


def corpus_for(model: ModelConfig) -> CorpusSpec:
    return E2E_CORPUS if model.name == "e2e_100m" else DEFAULT_CORPUS
