"""Synthetic SST-2-like corpus, generated statelessly from (seed, index).

This is the python half of a dual implementation: rust/src/data/corpus.rs
implements byte-identical logic (same SplitMix64 stream, same draw order).
Golden batches emitted by aot.py pin the two together; any divergence fails
rust integration tests.

Draw order per example (ABI — keep in sync with corpus.rs):
  1. label        <- next() & 1
  2. L            <- min_len + next() % (seq - min_len)
  3. n_signal     <- signal_min + next() % (signal_max - signal_min + 1)
  4. per content position j = 1..L-1 (position 0 is CLS):
       signal?   <- next() % remaining_positions < remaining_signal
       if signal:  contra? <- f64(next()) < contra
                   token   <- 2 + lex * lexicon_class + next() % lex
       else:       token   <- 2 + 2*lex + next() % n_neutral
  5. flip?        <- f64(next()) < noise
"""

from __future__ import annotations

from typing import Tuple

import numpy as np

from .configs import CorpusSpec

MASK64 = (1 << 64) - 1
GOLDEN = 0x9E3779B97F4A7C15

PAD, CLS = 0, 1
TEST_INDEX_BASE = 1 << 20  # train indices [0, 2^20); test indices start here


def _mix(z: int) -> int:
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK64
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK64
    return z ^ (z >> 31)


class SplitMix64:
    """Matches rust/src/rng/splitmix.rs exactly."""

    def __init__(self, seed: int):
        self.state = seed & MASK64

    def next_u64(self) -> int:
        self.state = (self.state + GOLDEN) & MASK64
        return _mix(self.state)

    def next_f64(self) -> float:
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))


def example_seed(spec_seed: int, index: int) -> int:
    return (spec_seed ^ (((index + 1) * GOLDEN) & MASK64)) & MASK64


def generate_example(
    spec: CorpusSpec, index: int
) -> Tuple[np.ndarray, np.ndarray, int, int]:
    """Returns (ids[seq] i32, mask[seq] f32, label, clean_label)."""
    rng = SplitMix64(example_seed(spec.seed, index))
    lex = spec.lexicon
    n_neutral = spec.vocab - 2 - 2 * lex
    assert n_neutral > 0, "vocab too small for lexicon"

    label = rng.next_u64() & 1
    length = spec.min_len + rng.next_u64() % (spec.seq - spec.min_len)
    n_signal = spec.signal_min + rng.next_u64() % (
        spec.signal_max - spec.signal_min + 1
    )
    content = length - 1  # position 0 is CLS
    n_signal = min(n_signal, content)

    ids = np.zeros(spec.seq, dtype=np.int32)
    mask = np.zeros(spec.seq, dtype=np.float32)
    ids[0] = CLS
    mask[:length] = 1.0

    remaining_signal = n_signal
    for j in range(1, length):
        remaining_positions = length - j
        is_signal = (rng.next_u64() % remaining_positions) < remaining_signal
        if is_signal:
            remaining_signal -= 1
            contra = rng.next_f64() < spec.contra
            cls_id = (1 - label) if contra else label
            tok = 2 + lex * cls_id + rng.next_u64() % lex
        else:
            tok = 2 + 2 * lex + rng.next_u64() % n_neutral
        ids[j] = tok
    flip = rng.next_f64() < spec.noise
    emitted = (1 - label) if flip else label
    return ids, mask, int(emitted), int(label)


def generate_batch(
    spec: CorpusSpec, start_index: int, batch: int
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Contiguous batch [start_index, start_index + batch)."""
    ids = np.zeros((batch, spec.seq), dtype=np.int32)
    mask = np.zeros((batch, spec.seq), dtype=np.float32)
    labels = np.zeros(batch, dtype=np.int32)
    for b in range(batch):
        ids[b], mask[b], labels[b], _ = generate_example(spec, start_index + b)
    return ids, mask, labels


def train_batch(spec: CorpusSpec, step: int, batch: int):
    return generate_batch(spec, step * batch, batch)


def test_batch(spec: CorpusSpec, step: int, batch: int):
    return generate_batch(spec, TEST_INDEX_BASE + step * batch, batch)
