"""AOT build: lower every L2 graph to HLO text + emit manifest and goldens.

Usage (from python/):  python -m compile.aot --out-dir ../artifacts [--full]

Outputs (all consumed by the rust coordinator, never by python at runtime):
  artifacts/<model>_<mode>_<fn>.hlo.txt   lowered HLO text (the interchange
      format: xla_extension 0.5.1 rejects jax>=0.5 serialized protos whose
      instruction ids are 64-bit; the text parser reassigns ids)
  artifacts/<model>_params.bin            pretrained flat f32 LE params
  artifacts/<model>_lora_init.bin         flat f32 LE LoRA init
  artifacts/toy_linreg_grad.hlo.txt       Fig. 2 toy oracle
  artifacts/manifest.json                 shapes/ABI/stats for everything
  artifacts/golden.json                   corpus + loss goldens pinning the
      rust reimplementation of the data pipeline and the PJRT runtime
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import corpus, model as M, params as P, pretrain
from .configs import (
    DEFAULT_PLAN,
    E2E_100M,
    MODELS,
    OPT_MINI,
    ROBERTA_MINI,
    corpus_for,
)

MANIFEST_VERSION = 3

TOY_D = 123  # a9a feature dimensionality
TOY_N = 512


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_to_file(fn, example_args, path: str) -> dict:
    t0 = time.time()
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    return {
        "file": os.path.basename(path),
        "bytes": len(text),
        "lower_seconds": round(time.time() - t0, 2),
        "inputs": [
            {"shape": list(a.shape), "dtype": str(a.dtype)} for a in example_args
        ],
    }


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def write_bin(path: str, arr: np.ndarray) -> dict:
    data = np.ascontiguousarray(arr, dtype=np.float32).tobytes()
    with open(path, "wb") as f:
        f.write(data)
    return {
        "file": os.path.basename(path),
        "len": int(arr.size),
        "sha256": hashlib.sha256(data).hexdigest(),
    }


def build_model(cfg, plan, out_dir: str, do_pretrain: bool) -> dict:
    cspec = corpus_for(cfg)
    d_ft = P.layout_size(P.ft_layout(cfg))
    d_lora = P.layout_size(P.lora_layout(cfg))
    b, s, k = plan.batch, cfg.max_seq, plan.k
    eb = plan.eval_batch

    entry: dict = {
        "config": {
            "vocab": cfg.vocab, "d_model": cfg.d_model,
            "n_layers": cfg.n_layers, "n_heads": cfg.n_heads,
            "d_ff": cfg.d_ff, "max_seq": cfg.max_seq,
            "n_classes": cfg.n_classes, "causal": cfg.causal,
            "pool": cfg.pool, "lora_rank": cfg.lora_rank,
            "lora_scale": cfg.lora_scale,
        },
        "d_ft": d_ft,
        "d_lora": d_lora,
        "batch": b,
        "eval_batch": eb,
        "k": k,
        "layout_ft": [
            {"name": n, "shape": list(sh)} for n, sh in P.ft_layout(cfg)
        ],
        "layout_lora": [
            {"name": n, "shape": list(sh)} for n, sh in P.lora_layout(cfg)
        ],
        "artifacts": {},
    }

    # --- parameters -------------------------------------------------------
    if do_pretrain:
        flat, stats = pretrain.adam_pretrain(cfg, cspec, plan)
        # fine-tuning gets a freshly initialized head (DESIGN.md §5): the
        # rust ZO runs start near chance accuracy with pretrained features
        flat = pretrain.reinit_head(cfg, flat)
        stats["init_accuracy"] = pretrain.eval_accuracy(
            cfg, cspec, flat, n_batches=4, batch=64
        )
        entry["pretrain"] = stats
    else:
        flat = np.asarray(P.init_ft(cfg, jax.random.PRNGKey(0)), np.float32)
        entry["pretrain"] = {"pretrain_steps": 0}
    entry["params"] = write_bin(os.path.join(out_dir, f"{cfg.name}_params.bin"), flat)

    layout = P.ft_layout(cfg)
    pdict = P.unflatten(jnp.asarray(flat), layout)
    lora0 = np.asarray(
        P.init_lora(cfg, jax.random.PRNGKey(1), head_w=pdict["head.w"],
                    head_b=pdict["head.b"]),
        np.float32,
    )
    entry["lora_init"] = write_bin(
        os.path.join(out_dir, f"{cfg.name}_lora_init.bin"), lora0
    )

    # --- HLO artifacts ------------------------------------------------------
    ids_s, mask_s = spec((b, s), jnp.int32), spec((b, s))
    lab_s = spec((b,), jnp.int32)
    ft = M.make_ft_fns(cfg)
    lora = M.make_lora_fns(cfg)

    def emit(name, fn, args):
        path = os.path.join(out_dir, f"{cfg.name}_{name}.hlo.txt")
        entry["artifacts"][name] = lower_to_file(fn, args, path)
        print(f"  {cfg.name}_{name}: {entry['artifacts'][name]['bytes']} bytes "
              f"({entry['artifacts'][name]['lower_seconds']}s)")

    emit("ft_logits", ft["logits"], (spec((d_ft,)), spec((eb, s), jnp.int32), spec((eb, s))))
    emit("ft_loss", ft["loss"], (spec((d_ft,)), ids_s, mask_s, lab_s))
    emit("ft_loss_dir", ft["loss_dir"],
         (spec((d_ft,)), spec((d_ft,)), spec(()), ids_s, mask_s, lab_s))
    emit("ft_loss_k", ft["loss_k"],
         (spec((d_ft,)), spec((k, d_ft)), spec(()), ids_s, mask_s, lab_s))

    emit("lora_logits", lora["logits"],
         (spec((d_ft,)), spec((d_lora,)), spec((eb, s), jnp.int32), spec((eb, s))))
    emit("lora_loss", lora["loss"],
         (spec((d_ft,)), spec((d_lora,)), ids_s, mask_s, lab_s))
    emit("lora_loss_dir", lora["loss_dir"],
         (spec((d_ft,)), spec((d_lora,)), spec((d_lora,)), spec(()), ids_s, mask_s, lab_s))
    emit("lora_loss_k", lora["loss_k"],
         (spec((d_ft,)), spec((d_lora,)), spec((k, d_lora)), spec(()), ids_s, mask_s, lab_s))

    return entry


def build_goldens(manifest: dict, out_dir: str) -> None:
    """Golden values pinning the rust corpus port + PJRT numerics."""
    golden: dict = {"corpus": [], "losses": {}}
    for name in manifest["models"]:
        cfg = MODELS[name]
        cspec = corpus_for(cfg)
        b = manifest["models"][name]["batch"]
        ids, mask, labels = corpus.train_batch(cspec, 0, b)
        tids, tmask, tlabels = corpus.test_batch(cspec, 0, b)
        golden["corpus"].append({
            "model": name,
            "train_ids": ids.tolist(), "train_mask": mask.tolist(),
            "train_labels": labels.tolist(),
            "test_ids": tids.tolist(), "test_mask": tmask.tolist(),
            "test_labels": tlabels.tolist(),
        })
        flat = np.fromfile(
            os.path.join(out_dir, f"{name}_params.bin"), dtype=np.float32
        )
        lora0 = np.fromfile(
            os.path.join(out_dir, f"{name}_lora_init.bin"), dtype=np.float32
        )
        ft = M.make_ft_fns(cfg)
        lo = M.make_lora_fns(cfg)
        args = (jnp.asarray(flat), jnp.asarray(ids), jnp.asarray(mask),
                jnp.asarray(labels))
        loss_ft = float(jax.jit(ft["loss"])(*args)[0])
        largs = (jnp.asarray(flat), jnp.asarray(lora0), jnp.asarray(ids),
                 jnp.asarray(mask), jnp.asarray(labels))
        loss_lora = float(jax.jit(lo["loss"])(*largs)[0])
        # deterministic direction the rust side can regenerate exactly:
        # d_i = 0.5 * sin(i)  (see rust/tests/runtime_golden.rs)
        dvec = (0.5 * np.sin(np.arange(flat.size, dtype=np.float64))).astype(
            np.float32
        )
        loss_dir = float(
            jax.jit(ft["loss_dir"])(
                jnp.asarray(flat), jnp.asarray(dvec), jnp.float32(1e-3),
                jnp.asarray(ids), jnp.asarray(mask), jnp.asarray(labels)
            )[0]
        )
        golden["losses"][name] = {
            "ft_loss_batch0": loss_ft,
            "lora_loss_batch0": loss_lora,
            "ft_loss_dir_batch0_sin_tau1e-3": loss_dir,
        }
    # toy golden: grad of linreg at fixed w, X, y
    rng = corpus.SplitMix64(0xA9A)
    w = np.array([((rng.next_u64() >> 11) * (1.0 / (1 << 53))) - 0.5
                  for _ in range(TOY_D)], np.float32)
    x = np.array([((rng.next_u64() >> 11) * (1.0 / (1 << 53))) - 0.5
                  for _ in range(TOY_N * TOY_D)], np.float32).reshape(TOY_N, TOY_D)
    y = np.array([((rng.next_u64() >> 11) * (1.0 / (1 << 53))) - 0.5
                  for _ in range(TOY_N)], np.float32)
    g, l = M.linreg_grad_fn(jnp.asarray(w), jnp.asarray(x), jnp.asarray(y))
    golden["toy"] = {
        "loss": float(l),
        "grad_head": np.asarray(g)[:8].tolist(),
        "grad_norm": float(np.linalg.norm(np.asarray(g))),
    }
    with open(os.path.join(out_dir, "golden.json"), "w") as f:
        json.dump(golden, f, indent=1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--full", action="store_true",
                    help="also build the e2e_100m artifacts (slow)")
    ap.add_argument("--no-pretrain", action="store_true",
                    help="skip Adam pretraining (tests only)")
    args = ap.parse_args()
    out_dir = os.path.abspath(args.out_dir)
    os.makedirs(out_dir, exist_ok=True)

    plan = DEFAULT_PLAN
    manifest: dict = {
        "version": MANIFEST_VERSION,
        "plan": {
            "batch": plan.batch, "eval_batch": plan.eval_batch, "k": plan.k,
        },
        "corpus": {},
        "models": {},
    }
    model_list = [ROBERTA_MINI, OPT_MINI] + ([E2E_100M] if args.full else [])
    for cfg in model_list:
        cspec = corpus_for(cfg)
        manifest["corpus"][cfg.name] = {
            "vocab": cspec.vocab, "seq": cspec.seq,
            "n_classes": cspec.n_classes, "lexicon": cspec.lexicon,
            "min_len": cspec.min_len, "signal_min": cspec.signal_min,
            "signal_max": cspec.signal_max, "contra": cspec.contra,
            "noise": cspec.noise, "seed": cspec.seed,
        }
        print(f"building {cfg.name} ...")
        do_pre = (not args.no_pretrain) and cfg.name != "e2e_100m"
        manifest["models"][cfg.name] = build_model(cfg, plan, out_dir, do_pre)

    # toy oracle (Fig. 2)
    toy = lower_to_file(
        M.linreg_grad_fn,
        (spec((TOY_D,)), spec((TOY_N, TOY_D)), spec((TOY_N,))),
        os.path.join(out_dir, "toy_linreg_grad.hlo.txt"),
    )
    manifest["toy"] = {"d": TOY_D, "n": TOY_N, **toy}

    build_goldens(manifest, out_dir)
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"manifest written to {out_dir}/manifest.json")


if __name__ == "__main__":
    main()
