"""AOT pipeline: HLO text emission and manifest schema (fast paths only —
the full build is exercised by `make artifacts` + the rust integration
tests)."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model as M


def test_to_hlo_text_emits_parseable_hlo(tmp_path):
    def fn(x, y):
        return (jnp.dot(x, y) + 1.0,)

    spec = jax.ShapeDtypeStruct((4, 4), jnp.float32)
    info = aot.lower_to_file(fn, (spec, spec), str(tmp_path / "t.hlo.txt"))
    text = (tmp_path / "t.hlo.txt").read_text()
    assert "HloModule" in text
    assert "ENTRY" in text
    assert info["bytes"] == len(text)
    assert info["inputs"][0]["shape"] == [4, 4]


def test_toy_graph_values():
    w = jnp.asarray(np.ones(3, np.float32))
    x = jnp.asarray(np.eye(3, dtype=np.float32))
    y = jnp.asarray(np.zeros(3, np.float32))
    grad, loss = M.linreg_grad_fn(w, x, y)
    # residual = w; loss = 0.5 * ||w||^2 / 3
    assert abs(float(loss) - 0.5) < 1e-6
    np.testing.assert_allclose(np.asarray(grad), np.ones(3) / 3, rtol=1e-6)


def test_write_bin_roundtrip(tmp_path):
    arr = np.arange(7, dtype=np.float32)
    info = aot.write_bin(str(tmp_path / "a.bin"), arr)
    assert info["len"] == 7
    back = np.fromfile(tmp_path / "a.bin", dtype=np.float32)
    np.testing.assert_array_equal(arr, back)


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__), "..", "..",
                                    "artifacts", "manifest.json")),
    reason="artifacts not built",
)
def test_built_manifest_consistency():
    root = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    with open(os.path.join(root, "manifest.json")) as f:
        m = json.load(f)
    assert m["version"] == aot.MANIFEST_VERSION
    for name, entry in m["models"].items():
        d_ft = sum(int(np.prod(l["shape"])) for l in entry["layout_ft"])
        assert d_ft == entry["d_ft"], name
        d_lora = sum(int(np.prod(l["shape"])) for l in entry["layout_lora"])
        assert d_lora == entry["d_lora"], name
        # every artifact file exists and is non-trivial
        for aname, info in entry["artifacts"].items():
            path = os.path.join(root, info["file"])
            assert os.path.exists(path), f"{name}/{aname}"
            assert os.path.getsize(path) > 1000
        params = os.path.join(root, entry["params"]["file"])
        assert os.path.getsize(params) == 4 * entry["d_ft"]
        lora = os.path.join(root, entry["lora_init"]["file"])
        assert os.path.getsize(lora) == 4 * entry["d_lora"]
