"""L1 correctness: every Pallas kernel vs its pure-jnp reference oracle.

Hypothesis sweeps shapes and seeds; tolerances are tight because
interpret=True executes the same f32 arithmetic as the reference.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    attention,
    axpy,
    layernorm,
    lora_matmul,
    perturb_normalize,
    ref,
)

settings.register_profile("ci", deadline=None, max_examples=20)
settings.load_profile("ci")


def rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape), jnp.float32)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("causal", [False, True])
@pytest.mark.parametrize("bh,s,dh", [(2, 8, 16), (6, 16, 32), (4, 32, 32)])
def test_attention_matches_ref(causal, bh, s, dh):
    rng = np.random.default_rng(bh * 100 + s)
    q, k, v = (rand(rng, bh, s, dh) for _ in range(3))
    # prefix-valid masks (the only shape the corpus produces)
    lens = rng.integers(1, s + 1, size=bh)
    mask = jnp.asarray(
        (np.arange(s)[None, :] < lens[:, None]).astype(np.float32)
    )
    out = attention(q, k, v, mask, causal=causal)
    expect = jnp.stack(
        [ref.attention_ref(q[i], k[i], v[i], mask[i], causal=causal)
         for i in range(bh)]
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-5, atol=1e-5)


@given(seed=st.integers(0, 2**31 - 1), s=st.sampled_from([4, 8, 16]),
       dh=st.sampled_from([8, 16, 32]))
def test_attention_hypothesis(seed, s, dh):
    rng = np.random.default_rng(seed)
    q, k, v = (rand(rng, 2, s, dh) for _ in range(3))
    mask = jnp.ones((2, s), jnp.float32)
    out = attention(q, k, v, mask, causal=False)
    expect = jnp.stack(
        [ref.attention_ref(q[i], k[i], v[i], mask[i]) for i in range(2)]
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-4, atol=1e-5)


def test_attention_rows_are_convex_combinations():
    # each output row lies in the convex hull of the V rows: max bound
    rng = np.random.default_rng(0)
    q, k = rand(rng, 2, 8, 16), rand(rng, 2, 8, 16)
    v = jnp.asarray(rng.uniform(0, 1, size=(2, 8, 16)), jnp.float32)
    mask = jnp.ones((2, 8), jnp.float32)
    out = np.asarray(attention(q, k, v, mask))
    assert out.max() <= float(np.asarray(v).max()) + 1e-5
    assert out.min() >= float(np.asarray(v).min()) - 1e-5


# ---------------------------------------------------------------------------
# perturb (axpy)
# ---------------------------------------------------------------------------

@given(seed=st.integers(0, 2**31 - 1),
       n=st.sampled_from([1, 17, 1000, 65536, 65537, 200_000]),
       scale=st.floats(-2.0, 2.0, allow_nan=False))
def test_axpy_matches_ref(seed, n, scale):
    rng = np.random.default_rng(seed)
    x, d = rand(rng, n), rand(rng, n)
    out = axpy(x, d, jnp.float32(scale))
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref.axpy_ref(x, d, scale)),
        rtol=1e-6, atol=1e-6,
    )


def test_axpy_zero_scale_is_identity():
    rng = np.random.default_rng(1)
    x, d = rand(rng, 1000), rand(rng, 1000)
    out = axpy(x, d, jnp.float32(0.0))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x))


def test_perturb_normalize_unit_step():
    rng = np.random.default_rng(2)
    x, d = rand(rng, 512), rand(rng, 512)
    out = perturb_normalize(x, d, jnp.float32(0.1))
    step = np.asarray(out) - np.asarray(x)
    assert abs(np.linalg.norm(step) - 0.1) < 1e-4


# ---------------------------------------------------------------------------
# lora matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("s,din,dout,r", [(8, 32, 64, 4), (16, 64, 200, 8),
                                          (32, 128, 128, 8)])
def test_lora_matches_ref(s, din, dout, r):
    rng = np.random.default_rng(s + dout)
    x, w = rand(rng, s, din), rand(rng, din, dout)
    a, b = rand(rng, din, r), rand(rng, r, dout)
    out = lora_matmul(x, w, a, b, 2.0)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref.lora_matmul_ref(x, w, a, b, 2.0)),
        rtol=1e-4, atol=1e-4,
    )


def test_lora_zero_b_equals_base_matmul():
    rng = np.random.default_rng(3)
    x, w = rand(rng, 8, 32), rand(rng, 32, 48)
    a = rand(rng, 32, 4)
    b = jnp.zeros((4, 48), jnp.float32)
    out = lora_matmul(x, w, a, b, 2.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x @ w),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# layernorm
# ---------------------------------------------------------------------------

@given(seed=st.integers(0, 2**31 - 1), n=st.sampled_from([1, 7, 128, 300]),
       d=st.sampled_from([8, 64, 128]))
def test_layernorm_matches_ref(seed, n, d):
    rng = np.random.default_rng(seed)
    x, g, b = rand(rng, n, d), rand(rng, d), rand(rng, d)
    out = layernorm(x, g, b)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref.layernorm_ref(x, g, b)),
        rtol=1e-4, atol=1e-5,
    )


def test_layernorm_output_standardized():
    rng = np.random.default_rng(4)
    x = rand(rng, 64, 128) * 10.0 + 3.0
    g = jnp.ones(128, jnp.float32)
    b = jnp.zeros(128, jnp.float32)
    out = np.asarray(layernorm(x, g, b))
    np.testing.assert_allclose(out.mean(axis=-1), 0.0, atol=1e-4)
    np.testing.assert_allclose(out.std(axis=-1), 1.0, atol=1e-2)
