"""L2 correctness: the kernel-backed forward vs the pure-jnp twin, the
artifact graphs' algebraic identities, and parameter-layout invariants."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import corpus, model as M, params as P
from compile.configs import DEFAULT_CORPUS, OPT_MINI, ROBERTA_MINI


CFGS = [ROBERTA_MINI, OPT_MINI]


def batch_for(cfg, n=4, start=0):
    spec = DEFAULT_CORPUS
    ids, mask, labels = corpus.generate_batch(spec, start, n)
    return jnp.asarray(ids), jnp.asarray(mask), jnp.asarray(labels)


@pytest.fixture(scope="module")
def flats():
    return {
        cfg.name: P.init_ft(cfg, jax.random.PRNGKey(0)) for cfg in CFGS
    }


@pytest.mark.parametrize("cfg", CFGS, ids=lambda c: c.name)
def test_kernel_forward_matches_pure(cfg, flats):
    flat = flats[cfg.name]
    layout = P.ft_layout(cfg)
    p = P.unflatten(flat, layout)
    ids, mask, _ = batch_for(cfg)
    out_kernel = M.forward(cfg, p, ids, mask)
    out_pure = M.forward_pure(cfg, p, ids, mask)
    np.testing.assert_allclose(np.asarray(out_kernel), np.asarray(out_pure),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("cfg", CFGS, ids=lambda c: c.name)
def test_kernel_forward_matches_pure_lora(cfg, flats):
    flat = flats[cfg.name]
    p = P.unflatten(flat, P.ft_layout(cfg))
    lora_flat = P.init_lora(cfg, jax.random.PRNGKey(5))
    # make the adapters non-trivial (B is zero-init by default)
    lora_flat = lora_flat.at[:].add(
        0.01 * jax.random.normal(jax.random.PRNGKey(6), lora_flat.shape)
    )
    lora = P.unflatten(lora_flat, P.lora_layout(cfg))
    ids, mask, _ = batch_for(cfg)
    out_kernel = M.forward(cfg, p, ids, mask, lora=lora)
    out_pure = M.forward_pure(cfg, p, ids, mask, lora=lora)
    np.testing.assert_allclose(np.asarray(out_kernel), np.asarray(out_pure),
                               rtol=1e-4, atol=1e-4)


def test_lora_zero_adapters_equal_base_ft():
    """LoRA with B=0 and the base head must reproduce the FT logits."""
    cfg = ROBERTA_MINI
    flat = P.init_ft(cfg, jax.random.PRNGKey(1))
    p = P.unflatten(flat, P.ft_layout(cfg))
    lora_flat = P.init_lora(cfg, jax.random.PRNGKey(2),
                            head_w=p["head.w"], head_b=p["head.b"])
    lora = P.unflatten(lora_flat, P.lora_layout(cfg))
    ids, mask, _ = batch_for(cfg)
    np.testing.assert_allclose(
        np.asarray(M.forward_pure(cfg, p, ids, mask, lora=lora)),
        np.asarray(M.forward_pure(cfg, p, ids, mask)),
        rtol=1e-5, atol=1e-5,
    )


def test_loss_dir_zero_equals_loss():
    cfg = ROBERTA_MINI
    flat = P.init_ft(cfg, jax.random.PRNGKey(3))
    fns = M.make_ft_fns(cfg)
    ids, mask, labels = batch_for(cfg, n=4)
    base = fns["loss"](flat, ids, mask, labels)[0]
    zero = jnp.zeros_like(flat)
    perturbed = fns["loss_dir"](flat, zero, jnp.float32(0.5), ids, mask, labels)[0]
    assert abs(float(base) - float(perturbed)) < 1e-6


def test_loss_k_equals_stacked_loss_dir():
    cfg = ROBERTA_MINI
    flat = P.init_ft(cfg, jax.random.PRNGKey(4))
    fns = M.make_ft_fns(cfg)
    ids, mask, labels = batch_for(cfg, n=4)
    k = 3
    dirs = jax.random.normal(jax.random.PRNGKey(9), (k, flat.size))
    tau = jnp.float32(1e-3)
    fused = fns["loss_k"](flat, dirs, tau, ids, mask, labels)[0]
    looped = jnp.stack([
        fns["loss_dir"](flat, dirs[i], tau, ids, mask, labels)[0]
        for i in range(k)
    ])
    np.testing.assert_allclose(np.asarray(fused), np.asarray(looped),
                               rtol=1e-6, atol=1e-6)


def test_causal_model_ignores_future_tokens():
    """opt_mini pools the last valid token; with causal masking, changing a
    PAD token *after* the last valid position must not change logits."""
    cfg = OPT_MINI
    flat = P.init_ft(cfg, jax.random.PRNGKey(7))
    p = P.unflatten(flat, P.ft_layout(cfg))
    ids, mask, _ = batch_for(cfg, n=2)
    out1 = M.forward_pure(cfg, p, ids, mask)
    ids2 = ids.at[:, -1].set(17)  # both rows have trailing padding
    assert float(mask[:, -1].sum()) == 0.0
    out2 = M.forward_pure(cfg, p, ids2, mask)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               rtol=1e-6, atol=1e-6)


def test_encoder_attends_bidirectionally():
    """roberta_mini (non-causal) pools [CLS]; changing a valid *later*
    token must change the [CLS] logits."""
    cfg = ROBERTA_MINI
    flat = P.init_ft(cfg, jax.random.PRNGKey(8))
    p = P.unflatten(flat, P.ft_layout(cfg))
    ids, mask, _ = batch_for(cfg, n=2)
    out1 = M.forward_pure(cfg, p, ids, mask)
    j = 5
    assert float(mask[0, j]) == 1.0
    ids2 = ids.at[0, j].set((int(ids[0, j]) % 100) + 200)
    out2 = M.forward_pure(cfg, p, ids2, mask)
    assert np.abs(np.asarray(out1[0]) - np.asarray(out2[0])).max() > 1e-7


def test_cross_entropy_uniform_is_log_c():
    logits = jnp.zeros((4, 2))
    labels = jnp.asarray([0, 1, 0, 1])
    ce = M.cross_entropy(logits, labels)
    assert abs(float(ce) - np.log(2.0)) < 1e-6


# ---------------------------------------------------------------------------
# parameter layout ABI
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cfg", CFGS, ids=lambda c: c.name)
def test_flatten_unflatten_roundtrip(cfg):
    layout = P.ft_layout(cfg)
    flat = P.init_ft(cfg, jax.random.PRNGKey(11))
    p = P.unflatten(flat, layout)
    flat2 = P.flatten(p, layout)
    np.testing.assert_array_equal(np.asarray(flat), np.asarray(flat2))


@pytest.mark.parametrize("cfg", CFGS, ids=lambda c: c.name)
def test_layout_sizes(cfg):
    d_ft = P.layout_size(P.ft_layout(cfg))
    d_lora = P.layout_size(P.lora_layout(cfg))
    assert d_ft > 1_000_000  # mini models are ~1-2M params
    assert d_lora < d_ft // 10  # LoRA is a small fraction
    # lora layout: 4 adapters per layer + head
    assert len(P.lora_layout(cfg)) == 4 * cfg.n_layers + 2


def test_layout_names_unique():
    for cfg in CFGS:
        names = [n for n, _ in P.ft_layout(cfg)]
        assert len(names) == len(set(names))
