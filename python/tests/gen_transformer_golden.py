"""Generate the transformer reference-parity golden for the rust oracle.

Runs the pure-jnp reference forward (`compile.model.forward_pure`) on two
tiny pinned configs — encoder-style (cls pool, bidirectional) and
decoder-style (last pool, causal) — over a fixed batch with padding, in
both FT and LoRA modes, and writes parameters, inputs and expected
logits/losses to rust/tests/golden/transformer_parity.json.

The rust test `transformer_golden.rs` replays the same forward from the
committed vectors and must match within 1e-5 (f32 forward, different
accumulation orders).  Regenerate with:

    cd python && PYTHONPATH=. python tests/gen_transformer_golden.py
"""

from __future__ import annotations

import json
import os

import numpy as np
import jax.numpy as jnp

from compile import model as M, params as P
from compile.configs import ModelConfig

OUT = os.path.join(
    os.path.dirname(__file__), "..", "..", "rust", "tests", "golden",
    "transformer_parity.json",
)

TINY_ENC = ModelConfig(
    name="tiny_enc", vocab=32, d_model=8, n_layers=2, n_heads=2, d_ff=16,
    max_seq=4, n_classes=2, causal=False, pool="cls", lora_rank=2,
    lora_scale=2.0,
)
TINY_DEC = ModelConfig(
    name="tiny_dec", vocab=32, d_model=8, n_layers=2, n_heads=2, d_ff=16,
    max_seq=4, n_classes=2, causal=True, pool="last", lora_rank=2,
    lora_scale=2.0,
)


def init_flat(layout, rng, lora=False):
    """Deterministic dense init: every tensor nonzero so parity exercises
    each term (unlike the training init, where lora B = 0 would zero the
    adapter delta entirely)."""
    parts = []
    for name, shape in layout:
        n = int(np.prod(shape))
        if name.endswith(".g"):
            vals = 1.0 + 0.1 * rng.standard_normal(n)
        elif lora:
            vals = 0.3 * rng.standard_normal(n)
        elif name.startswith(("tok_emb", "pos_emb")):
            vals = 0.5 * rng.standard_normal(n)
        else:
            vals = 0.2 * rng.standard_normal(n)
        parts.append(vals.astype(np.float32))
    return np.concatenate(parts)


def case(cfg: ModelConfig, seed: int):
    rng = np.random.default_rng(seed)
    base = init_flat(P.ft_layout(cfg), rng)
    lora = init_flat(P.lora_layout(cfg), rng, lora=True)
    b, s = 3, cfg.max_seq
    ids = rng.integers(1, cfg.vocab, size=(b, s)).astype(np.int32)
    mask = np.ones((b, s), np.float32)
    mask[1, 3:] = 0.0
    mask[2, 2:] = 0.0
    ids[mask == 0.0] = 0  # PAD
    labels = np.array([0, 1, 0], np.int32)

    jids, jmask, jlabels = jnp.asarray(ids), jnp.asarray(mask), jnp.asarray(labels)
    p = P.unflatten(jnp.asarray(base), P.ft_layout(cfg))
    lp = P.unflatten(jnp.asarray(lora), P.lora_layout(cfg))

    ft_logits = M.forward_pure(cfg, p, jids, jmask)
    ft_loss = M.cross_entropy(ft_logits, jlabels)
    lo_logits = M.forward_pure(cfg, p, jids, jmask, lora=lp)
    lo_loss = M.cross_entropy(lo_logits, jlabels)

    return {
        "name": cfg.name,
        "spec": {
            "vocab": cfg.vocab, "d_model": cfg.d_model,
            "n_layers": cfg.n_layers, "n_heads": cfg.n_heads,
            "d_ff": cfg.d_ff, "max_seq": cfg.max_seq,
            "n_classes": cfg.n_classes, "causal": cfg.causal,
            "pool": cfg.pool, "lora_rank": cfg.lora_rank,
            "lora_scale": cfg.lora_scale, "lora_targets": "qv",
        },
        "batch": {
            "b": b, "seq": s,
            "ids": ids.reshape(-1).tolist(),
            "mask": mask.reshape(-1).tolist(),
            "labels": labels.tolist(),
        },
        "base": [float(v) for v in base],
        "lora": [float(v) for v in lora],
        "ft": {
            "logits": [float(v) for v in np.asarray(ft_logits).reshape(-1)],
            "loss": float(ft_loss),
        },
        "lora_mode": {
            "logits": [float(v) for v in np.asarray(lo_logits).reshape(-1)],
            "loss": float(lo_loss),
        },
    }


def main():
    doc = {
        "generator": "python/tests/gen_transformer_golden.py "
                     "(compile.model.forward_pure, jax f32)",
        "tolerance": 1e-5,
        "cases": [case(TINY_ENC, 0xC0FFEE), case(TINY_DEC, 0xBEEF)],
    }
    os.makedirs(os.path.dirname(OUT), exist_ok=True)
    with open(OUT, "w") as f:
        json.dump(doc, f, separators=(",", ":"))
        f.write("\n")
    for c in doc["cases"]:
        print(c["name"], "ft", c["ft"]["logits"][:2], c["ft"]["loss"],
              "lora", c["lora_mode"]["loss"])
    print("wrote", os.path.normpath(OUT))


if __name__ == "__main__":
    main()
