"""Corpus generator invariants + the SplitMix64 ABI test vectors that pin
the rust port (rust/src/rng/splitmix.rs has the mirror test)."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.configs import CorpusSpec, DEFAULT_CORPUS
from compile.corpus import (
    CLS,
    PAD,
    SplitMix64,
    TEST_INDEX_BASE,
    generate_batch,
    generate_example,
)

settings.register_profile("ci", deadline=None, max_examples=25)
settings.load_profile("ci")


def test_splitmix_reference_vector():
    r = SplitMix64(0)
    assert r.next_u64() == 0xE220A8397B1DCDAF
    assert r.next_u64() == 0x6E789E6AA1B965F4
    assert r.next_u64() == 0x06C45D188009454F
    assert r.next_u64() == 0xF88BB8A8724C81EC


def test_example_deterministic():
    a = generate_example(DEFAULT_CORPUS, 123)
    b = generate_example(DEFAULT_CORPUS, 123)
    np.testing.assert_array_equal(a[0], b[0])
    assert a[2] == b[2]


@given(index=st.integers(0, 10_000))
def test_example_structure(index):
    ids, mask, label, clean = generate_example(DEFAULT_CORPUS, index)
    assert ids.shape == (DEFAULT_CORPUS.seq,)
    assert ids[0] == CLS
    assert label in (0, 1) and clean in (0, 1)
    # prefix mask
    length = int(mask.sum())
    assert DEFAULT_CORPUS.min_len <= length < DEFAULT_CORPUS.seq
    assert (mask[:length] == 1.0).all() and (mask[length:] == 0.0).all()
    # padding is PAD; valid tokens are in-vocab
    assert (ids[length:] == PAD).all()
    assert (ids[1:length] >= 2).all()
    assert (ids[:length] < DEFAULT_CORPUS.vocab).all()


def test_labels_balanced():
    _, _, labels = generate_batch(DEFAULT_CORPUS, 0, 2000)
    frac = labels.mean()
    assert abs(frac - 0.5) < 0.05


def test_noise_rate_close_to_spec():
    flips = 0
    n = 3000
    for i in range(n):
        _, _, label, clean = generate_example(DEFAULT_CORPUS, i)
        flips += int(label != clean)
    rate = flips / n
    assert abs(rate - DEFAULT_CORPUS.noise) < 0.015


def test_train_test_streams_disjoint():
    tr = generate_batch(DEFAULT_CORPUS, 0, 8)
    te = generate_batch(DEFAULT_CORPUS, TEST_INDEX_BASE, 8)
    assert not np.array_equal(tr[0], te[0])


def test_signal_majority_tracks_clean_label():
    lex = DEFAULT_CORPUS.lexicon
    agree = total = 0
    for i in range(500):
        ids, _, _, clean = generate_example(DEFAULT_CORPUS, i)
        pos = ((ids >= 2) & (ids < 2 + lex)).sum()
        neg = ((ids >= 2 + lex) & (ids < 2 + 2 * lex)).sum()
        if pos != neg:
            total += 1
            agree += int((0 if pos > neg else 1) == clean)
    assert agree / total > 0.9


def test_different_seeds_different_corpora():
    spec2 = CorpusSpec(vocab=4096, seq=32, seed=999)
    a = generate_example(DEFAULT_CORPUS, 0)[0]
    b = generate_example(spec2, 0)[0]
    assert not np.array_equal(a, b)
