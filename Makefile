# Build orchestration for the three-layer stack (see README.md).
#
#   make artifacts     run L2+L1: lower models + kernels to artifacts/
#   make build         compile the L3 coordinator (release)
#   make test          tier-1 verify: cargo build --release && cargo test -q
#   make doc           API docs, warnings fatal (CI parity)
#   make bench         regenerate tables/figures from the artifacts
#   make bench-smoke   compile + run ONE iteration of every bench (CI rot guard)

.PHONY: artifacts build test doc bench bench-smoke clean

artifacts:
	cd python && python -m compile.aot --out-dir ../artifacts

build:
	cargo build --release

test: build
	cargo test -q

doc:
	RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

bench:
	cargo bench

bench-smoke:
	cargo bench -- --smoke

clean:
	cargo clean
	rm -rf artifacts
