# Build orchestration for the three-layer stack (see README.md).
#
#   make artifacts       run L2+L1: lower models + kernels to artifacts/
#   make build           compile the L3 coordinator (release)
#   make test            tier-1 verify: cargo build --release && cargo test -q
#   make test-streamed   the test suite with streamed (seed-replay) probe
#                        storage forced for every Trainer (CI parity)
#   make test-resume     the interrupt-resume suite under both probe-
#                        storage modes (CI parity for the resume-smoke job)
#   make lint            clippy, warnings fatal (CI parity; allow-list in ci.yml)
#   make doc             API docs, warnings fatal (CI parity)
#   make bench           regenerate tables/figures from the artifacts
#   make bench-smoke     compile + run ONE iteration of every bench (CI rot
#                        guard; includes one mem/* probe-storage row)

.PHONY: artifacts build test test-streamed test-resume lint doc bench bench-smoke clean

artifacts:
	cd python && python -m compile.aot --out-dir ../artifacts

build:
	cargo build --release

test: build
	cargo test -q

test-streamed: build
	ZO_PROBE_STORAGE=streamed cargo test -q

test-resume: build
	ZO_PROBE_STORAGE=materialized cargo test -q --test checkpoint_resume
	ZO_PROBE_STORAGE=streamed cargo test -q --test checkpoint_resume

lint:
	cargo clippy --all-targets -- -D warnings \
	  -A clippy::needless-range-loop -A clippy::manual-div-ceil \
	  -A clippy::too-many-arguments -A clippy::new-without-default \
	  -A clippy::manual-memcpy -A clippy::comparison-chain \
	  -A clippy::type-complexity

doc:
	RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

bench:
	cargo bench

# smoke mode clamps every bench to one iteration; perf_hotpath keeps one
# mem/bestofk5_d1M_{materialized,streamed} pair in smoke so the probe-
# storage rows cannot rot
bench-smoke:
	cargo bench -- --smoke

clean:
	cargo clean
	rm -rf artifacts
