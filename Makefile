# Build orchestration for the three-layer stack (see README.md).
#
#   make artifacts       run L2+L1: lower models + kernels to artifacts/
#   make build           compile the L3 coordinator (release)
#   make test            tier-1 verify: cargo build --release && cargo test -q
#   make test-streamed   the test suite with streamed (seed-replay) probe
#                        storage forced for every Trainer (CI parity)
#   make test-resume     the interrupt-resume suite under both probe-
#                        storage modes (CI parity for the resume-smoke job)
#   make test-mlp        the MLP oracle integration suite under both
#                        probe-storage modes (CI parity)
#   make test-transformer  the transformer + LoRA oracle suite (reference
#                        parity golden + train matrix) under both probe-
#                        storage modes (CI parity for the table1-smoke job)
#   make test-store      the content-addressed store suite: store/lock/
#                        snapshot unit tests plus the integration matrix
#                        (corruption, GC, warm-start short-circuit, legacy
#                        v2 migration) under both probe-storage modes
#                        (CI parity for the store-smoke job)
#   make test-service    the distributed-service suite: loopback
#                        coordinator + worker farming (byte-identical to
#                        single-process), lease-expiry fault injection,
#                        eval-shard merge, malformed-wire handling, HTTP
#                        parser unit tests, and the env/flag precedence
#                        contract (CI parity for the service-smoke job)
#   make test-lanes      the full test suite under ZO_LANES=scalar and
#                        ZO_LANES=wide — the lane-accumulation contract
#                        (DESIGN.md §14) says every result is bitwise
#                        identical either way, so both runs must pass
#                        identically (CI parity)
#   make test-gemm       the GEMM-heavy suites (gemm contract + mlp +
#                        transformer) under ZO_GEMM=reference and under
#                        ZO_GEMM=blocked + ZO_LANES=wide — the tiling
#                        contract (DESIGN.md §15) says every result is
#                        bitwise identical either way (CI parity)
#   make lint            clippy, warnings fatal (CI parity; allow-list in ci.yml)
#   make fmt             rustfmt check only (CI parity)
#   make doc             API docs, warnings fatal (CI parity)
#   make bench           regenerate tables/figures from the artifacts
#   make bench-smoke     compile + run ONE iteration of every bench (CI rot
#                        guard; includes one mem/* probe-storage row) and
#                        serialize the perf_hotpath rows to $(BENCH_OUT)
#   make bench-baseline  regenerate the committed bench baseline (same
#                        smoke mode as the gate compares against, so like
#                        compares with like); run on the reference runner
#                        and commit $(BENCH_BASELINE)
#   make bench-gate      diff $(BENCH_OUT) against $(BENCH_BASELINE) with
#                        +/-20% thresholds on the loss_k / axpy_k /
#                        probe_combine / mlp / transformer / mem / lanes /
#                        qstore rows (ns/op + peak bytes, separately
#                        tunable), plus the intra-run lanes/* scalar-vs-
#                        wide A/B ratio check (wide must run in at most
#                        $(BENCH_AB_MAX_RATIO)x the scalar time — i.e. a
#                        >= 1.5x speedup — measured within one run, so no
#                        stored timing anchor is involved) and the
#                        per-family $(BENCH_AB_SPECS) pairs — every
#                        gemm/*_blocked row must beat its *_reference
#                        sibling from the same run (the GEMM engine's
#                        enforced speedup, DESIGN.md §15)

.PHONY: artifacts build test test-streamed test-resume test-mlp \
        test-transformer test-store test-service test-lanes test-gemm \
        lint fmt doc bench bench-smoke bench-baseline bench-gate clean

# Bench-regression gate knobs (DESIGN.md §12).  BENCH_JSON must reach the
# bench binary as an absolute path: cargo runs benches with cwd = the
# package root (rust/), while bench-gate and CI read from the repo root.
BENCH_OUT ?= BENCH_current.json
BENCH_BASELINE ?= rust/benches/BENCH_baseline.json
BENCH_GATES ?= loss_k,axpy_k,probe_combine,mlp,transformer,mem/,lanes/,qstore/,gemm/,snapshot/
BENCH_THRESHOLD ?= 0.20
BENCH_BYTES_THRESHOLD ?= 0.20
BENCH_AB_MAX_RATIO ?= 0.67
BENCH_AB_PREFIX ?= lanes/
# Intra-run slow/fast families (prefix:slow:fast:ratio).  gemm/tfm_* is
# the tentpole acceptance bound: blocked must run in at most 0.5x the
# reference time (>= 2x speedup) at the transformer projection shape.
BENCH_AB_SPECS ?= gemm/tfm:reference:blocked:0.5,gemm/mlp:reference:blocked:0.67
BENCH_OUT_ABS = $(abspath $(BENCH_OUT))
BENCH_BASELINE_ABS = $(abspath $(BENCH_BASELINE))

artifacts:
	cd python && python -m compile.aot --out-dir ../artifacts

build:
	cargo build --release

test: build
	cargo test -q

test-streamed: build
	ZO_PROBE_STORAGE=streamed cargo test -q

test-resume: build
	ZO_PROBE_STORAGE=materialized cargo test -q --test checkpoint_resume
	ZO_PROBE_STORAGE=streamed cargo test -q --test checkpoint_resume

test-mlp: build
	ZO_PROBE_STORAGE=materialized cargo test -q --test mlp_train
	ZO_PROBE_STORAGE=streamed cargo test -q --test mlp_train

test-transformer: build
	ZO_PROBE_STORAGE=materialized cargo test -q --test transformer_golden --test transformer_train
	ZO_PROBE_STORAGE=streamed cargo test -q --test transformer_golden --test transformer_train

test-store: build
	cargo test -q --lib store::
	cargo test -q --lib snapshot::
	ZO_PROBE_STORAGE=materialized cargo test -q --test store --test checkpoint_resume
	ZO_PROBE_STORAGE=streamed cargo test -q --test store --test checkpoint_resume

test-service: build
	cargo test -q --lib service::
	cargo test -q --test service --test precedence

test-lanes: build
	ZO_LANES=scalar cargo test -q
	ZO_LANES=wide cargo test -q

test-gemm: build
	ZO_GEMM=reference cargo test -q --test gemm_contract --test mlp_train --test transformer_golden --test transformer_train
	ZO_GEMM=blocked ZO_LANES=wide cargo test -q --test gemm_contract --test mlp_train --test transformer_golden --test transformer_train

lint:
	cargo clippy --all-targets -- -D warnings \
	  -A clippy::needless-range-loop -A clippy::manual-div-ceil \
	  -A clippy::too-many-arguments -A clippy::new-without-default \
	  -A clippy::manual-memcpy -A clippy::comparison-chain \
	  -A clippy::type-complexity

fmt:
	cargo fmt --all -- --check

doc:
	RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

bench:
	cargo bench

# smoke mode clamps every bench to one iteration; perf_hotpath keeps one
# mem/bestofk5_d1M_{materialized,streamed} pair in smoke so the probe-
# storage rows cannot rot.  The second invocation re-runs perf_hotpath
# with BENCH_JSON set so the regression gate has rows to diff.
bench-smoke:
	cargo bench -- --smoke
	BENCH_JSON=$(BENCH_OUT_ABS) cargo bench --bench perf_hotpath -- --smoke

bench-baseline:
	BENCH_JSON=$(BENCH_BASELINE_ABS) cargo bench --bench perf_hotpath -- --smoke

bench-gate: bench-smoke
	cargo run --release --bin bench-gate -- \
	  --baseline $(BENCH_BASELINE_ABS) --current $(BENCH_OUT_ABS) \
	  --threshold $(BENCH_THRESHOLD) --bytes-threshold $(BENCH_BYTES_THRESHOLD) \
	  --gate $(BENCH_GATES) \
	  --ab-max-ratio $(BENCH_AB_MAX_RATIO) --ab-prefix $(BENCH_AB_PREFIX) \
	  --ab-specs $(BENCH_AB_SPECS)

clean:
	cargo clean
	rm -rf artifacts
	rm -f BENCH_current.json
